//! `ab`: the adaptation-policy A/B harness — every replan policy × the
//! dynamic scenario suite, on identical request streams.
//!
//! The harness materializes each scenario ONCE (the build is
//! deterministic in its seed) and replays the exact same arrival stream
//! through a static reference run and through every `policy ×
//! {cold, warm} × {blackout, staged}` combination, so differences in the
//! comparison table are attributable to the adaptation policy (or the
//! migration executor) alone — the AlpaServe-style controlled comparison
//! ROADMAP's "Adaptation policy" item asked for.
//!
//! Per cell it reports SLO attainment, p99 latency, migration count,
//! replan count, total migration downtime (LLM-seconds) and priced
//! migration cost, KV-copy resumes, and the replan decision latency
//! (placement-search wall time, from [`ReplanOutcome::decision_ms`]).
//! Everything except the wall-clock latency columns is deterministic:
//! two runs with the same config produce byte-identical
//! `to_json(false)` / `to_markdown(false)` output (pinned by a test),
//! which is what makes the table trustworthy evidence for the
//! default-flip contracts: the minimum warm−cold SLO delta and parity
//! verdict against [`WARM_PARITY_EPS`], the worst staged−blackout
//! downtime delta (negative everywhere ⇒ staged strictly cheaper) that
//! gates the `migration_mode` default, and — when fault axes are
//! requested — the minimum recover−ignore SLO delta over the chaos
//! cells (positive everywhere ⇒ failure-aware recovery pays for
//! itself) that gates the `fault_recovery` default.
//!
//! Two opt-in sections extend the grid: `disagg` runs the long-context
//! length shapes twice — mixed placement vs prefill/decode
//! disaggregation + chunked prefill — on identical streams, reporting
//! p99 TTFT beside SLO and the `disagg_slo_delta_min` /
//! `disagg_ttft_delta_max` verdict pair that gates the `disagg`
//! default flip; `sweep_forecast` grids ForecastPolicy's gain ×
//! horizon knobs over the forecastable shapes so the two parameters
//! get harness columns instead of folklore defaults.
//!
//! [`ReplanOutcome::decision_ms`]: crate::simulator::ReplanOutcome

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::bench::drift::{
    run_scenario_cfg, run_scenario_faults, scenario_cluster,
};
use crate::coordinator::migration::MigrationMode;
use crate::coordinator::replan::PolicyKind;
use crate::coordinator::{EngineConfig, ReplanConfig};
use crate::memory::EvictionKind;
use crate::simulator::FaultsAxis;
use crate::util::json::Json;
use crate::workload::{Scenario, ScenarioShape, SloClass};

/// Warm-start counts as SLO-parity when the worst warm−cold attainment
/// delta across all policy × scenario cells is no lower than this.
pub const WARM_PARITY_EPS: f64 = 0.02;

/// Knobs of one `ab` run.
#[derive(Clone, Debug)]
pub struct AbConfig {
    /// Simulated seconds per run.
    pub duration: f64,
    /// Workload seed (shared by every cell — identical streams).
    pub seed: u64,
    /// Policies to compare.
    pub policies: Vec<PolicyKind>,
    /// Scenario shapes to run.
    pub shapes: Vec<ScenarioShape>,
    /// Overload shapes for the tier section: each runs once tier-blind
    /// (FCFS admission, no shedding) and once tier-aware + shedding, on
    /// identical streams, scored on tier-weighted goodput.
    pub overload_shapes: Vec<ScenarioShape>,
    /// Warm-start modes crossed with the policies.
    pub warm_modes: Vec<bool>,
    /// Migration executors crossed with everything else.
    pub migration_modes: Vec<MigrationMode>,
    /// SLO scale for attainment reporting.
    pub slo_scale: f64,
    /// KV eviction policy for every run in the grid (the cache layer is
    /// off at [`EvictionKind::None`] — the pre-cache engine).
    pub eviction: EvictionKind,
    /// Host-DRAM tier capacity in blocks per unit (0 = no host tier).
    pub host_tier_blocks: usize,
    /// Chaos schedules for the fault section: each axis runs every
    /// scenario shape twice — faults ignored vs failure-aware recovery
    /// — on identical streams and identical fault schedules.
    /// [`FaultsAxis::None`] entries are skipped (nothing to inject).
    /// Empty (the default) skips the section entirely.
    pub faults: Vec<FaultsAxis>,
    /// Opt-in disaggregation section: run every length shape twice —
    /// mixed placement (the default engine) vs phase-role placement +
    /// chunked prefill — on identical streams. Off by default: the
    /// section costs two full runs per shape.
    pub disagg: bool,
    /// Length shapes for the disagg section (ignored unless `disagg`).
    pub length_shapes: Vec<ScenarioShape>,
    /// Chunk size (prompt tokens) for the disagg `on` arm's chunked
    /// prefill; 0 would disable chunking there.
    pub chunk_prefill_tokens: usize,
    /// Opt-in forecast sweep: grid ForecastPolicy's gain × horizon
    /// knobs over the forecastable shapes (flash-crowd, drift).
    pub sweep_forecast: bool,
}

impl AbConfig {
    /// The full comparison: three policies × the four dynamic scenarios
    /// × {cold, warm} × {blackout, staged}, at the scenario default
    /// duration.
    pub fn full() -> AbConfig {
        AbConfig {
            duration: 120.0,
            seed: 2024,
            policies: PolicyKind::all().to_vec(),
            shapes: ScenarioShape::dynamic().to_vec(),
            overload_shapes: ScenarioShape::overload().to_vec(),
            warm_modes: vec![false, true],
            migration_modes: MigrationMode::all().to_vec(),
            slo_scale: 8.0,
            eviction: EvictionKind::None,
            host_tier_blocks: 0,
            faults: Vec::new(),
            disagg: false,
            length_shapes: ScenarioShape::length().to_vec(),
            chunk_prefill_tokens: 256,
            sweep_forecast: false,
        }
    }

    /// CI smoke: the same grid, shorter runs.
    pub fn smoke() -> AbConfig {
        AbConfig { duration: 60.0, ..AbConfig::full() }
    }
}

/// One adaptive run's row in the comparison.
#[derive(Clone, Debug)]
pub struct AbCell {
    pub shape: &'static str,
    pub policy: &'static str,
    pub warm: bool,
    /// Migration executor ("blackout" | "staged").
    pub migration: &'static str,
    pub arrived: usize,
    pub completed: usize,
    pub dropped: usize,
    /// SLO attainment at the configured scale (rounded to 1e-4).
    pub slo: f64,
    /// Tier-weighted goodput at the configured scale, req-weight/s
    /// (rounded to 1e-4).
    pub goodput: f64,
    /// p99 request latency, seconds (rounded to 1e-3). `None` when the
    /// run completed nothing — an explicitly empty cell, never a NaN
    /// that would poison every verdict comparison downstream.
    pub p99_latency: Option<f64>,
    pub replans: usize,
    pub migrations: usize,
    /// Σ per-LLM migration unavailability, LLM-seconds (rounded 1e-4).
    pub downtime_s: f64,
    /// Σ migration cost charged to the policy (rounded 1e-4).
    pub migration_cost: f64,
    /// Requests resumed from copied KV without recompute.
    pub kv_resumed: usize,
    /// Replan decision latency (placement-search wall time), mean and
    /// max milliseconds over fired checks; 0 when none fired.
    /// Host-dependent — excluded from the deterministic outputs.
    pub decision_ms_mean: f64,
    pub decision_ms_max: f64,
}

/// The static (never-replan) reference row for one scenario.
#[derive(Clone, Debug)]
pub struct AbBaseline {
    pub shape: &'static str,
    pub arrived: usize,
    pub completed: usize,
    pub slo: f64,
    /// Tier-weighted goodput at the configured scale (rounded 1e-4).
    pub goodput: f64,
    /// `None` when the static run completed nothing (see
    /// [`AbCell::p99_latency`]).
    pub p99_latency: Option<f64>,
}

/// One run in the tiered-overload section: an overload scenario served
/// either tier-blind (`mode == "fcfs"`: arrival order, no admission
/// control) or tier-aware (`mode == "tiered"`: slack-per-weight
/// scheduling + load shedding), on the identical request stream.
#[derive(Clone, Debug)]
pub struct AbTierCell {
    pub shape: &'static str,
    /// "fcfs" | "tiered".
    pub mode: &'static str,
    pub arrived: usize,
    pub completed: usize,
    /// Requests shed at admission, by tier (interactive, standard,
    /// batch).
    pub shed: [u64; 3],
    /// Tier-weighted goodput at the configured scale (rounded 1e-4).
    pub goodput: f64,
    /// SLO attainment over completions (rounded 1e-4).
    pub slo: f64,
    /// Per-tier goodput (interactive, standard, batch; rounded 1e-4).
    pub tier_goodput: [f64; 3],
    /// Per-tier p99 latency, seconds; `None` where the tier completed
    /// nothing (rounded 1e-3).
    pub tier_p99: [Option<f64>; 3],
}

/// One run in the chaos section: a scenario served under a seeded
/// fault schedule, either ignoring the faults (`mode == "ignore"`: the
/// dead unit's work is lost and its LLMs stay dark) or with
/// failure-aware recovery (`mode == "recover"`: emergency replan over
/// the survivors, host-tier resume, KV-copy retries). Scored on SLO
/// attainment over ARRIVED requests, so lost requests count against
/// the run — a completions-only ratio would reward losing them.
#[derive(Clone, Debug)]
pub struct AbFaultCell {
    pub shape: &'static str,
    /// Fault axis ("single-unit" | "rolling" | ...).
    pub faults: &'static str,
    /// "ignore" | "recover".
    pub mode: &'static str,
    pub arrived: usize,
    pub completed: usize,
    /// Requests lost to faults (device KV destroyed, no recovery path).
    pub lost: usize,
    /// Meets-SLO completions / arrived, at the configured scale
    /// (rounded 1e-4).
    pub slo: f64,
    /// Requests that resumed from surviving host-tier KV (no re-prefill).
    pub kv_recovered: usize,
    /// Prefill tokens re-run because device KV died with the unit.
    pub tokens_recomputed: u64,
    /// Mean time to restore service over failure episodes, seconds
    /// (rounded 1e-4); `None` when no unit failed.
    pub mttr_s: Option<f64>,
    /// Worst per-LLM availability (1 − downtime/duration; rounded
    /// 1e-4); `None` when the run tracked no LLMs.
    pub availability_min: Option<f64>,
}

/// One run in the disaggregation section: a long-context length shape
/// served either by the default mixed placement (`mode == "off"`) or
/// by phase-role (prefill/decode) placement with chunked prefill
/// (`mode == "on"`), on the identical request stream. TTFT is the
/// headline metric — disaggregation exists to stop long prompts from
/// head-of-line-blocking time-to-first-token.
#[derive(Clone, Debug)]
pub struct AbDisaggCell {
    pub shape: &'static str,
    /// "off" | "on".
    pub mode: &'static str,
    pub arrived: usize,
    pub completed: usize,
    pub dropped: usize,
    /// SLO attainment at the configured scale (rounded 1e-4).
    pub slo: f64,
    /// Tier-weighted goodput at the configured scale (rounded 1e-4).
    pub goodput: f64,
    /// p99 time-to-first-token, seconds (rounded 1e-3); `None` when
    /// the run completed nothing.
    pub p99_ttft: Option<f64>,
    /// p99 end-to-end latency, seconds (rounded 1e-3).
    pub p99_latency: Option<f64>,
    /// Prefill→decode handoffs that resumed from copied KV (0 in the
    /// off arm; 0 in an on arm whose disagg search fell back to mixed).
    pub kv_resumed: usize,
}

/// One run in the forecast sweep: ForecastPolicy at a (gain, horizon)
/// grid point on one forecastable shape.
#[derive(Clone, Debug)]
pub struct AbForecastCell {
    pub shape: &'static str,
    pub gain: f64,
    pub horizon: f64,
    pub slo: f64,
    pub goodput: f64,
    pub p99_latency: Option<f64>,
    pub replans: usize,
    pub migrations: usize,
}

/// Everything one `ab` invocation measured.
#[derive(Clone, Debug)]
pub struct AbReport {
    pub duration: f64,
    pub seed: u64,
    pub slo_scale: f64,
    pub baselines: Vec<AbBaseline>,
    pub cells: Vec<AbCell>,
    /// The tiered-overload section (empty when no overload shapes ran).
    pub tier_cells: Vec<AbTierCell>,
    /// Minimum warm−cold SLO delta over all (policy, shape, migration)
    /// triples that ran in both modes (None when the grid held no such
    /// pair).
    pub warm_delta_min: Option<f64>,
    /// Worst (maximum) staged−blackout downtime delta over all
    /// (policy, shape, warm) triples that ran both executors: negative
    /// everywhere means staged strictly undercuts blackout on lost
    /// service — the `migration_mode` default-flip gate.
    pub staged_downtime_delta_max: Option<f64>,
    /// Minimum staged−blackout SLO delta over the same pairs (staged
    /// must not buy its downtime win with attainment).
    pub staged_slo_delta_min: Option<f64>,
    /// Minimum tiered−fcfs goodput delta over the overload shapes:
    /// positive everywhere means tier-aware scheduling + shedding
    /// strictly beats tier-blind FCFS on tier-weighted goodput — the
    /// gate for defaulting the tier engine on under overload.
    pub shed_goodput_delta_min: Option<f64>,
    /// The chaos section (empty when no fault axes ran).
    pub fault_cells: Vec<AbFaultCell>,
    /// Minimum recover−ignore SLO delta over matched (shape, axis)
    /// fault pairs: positive everywhere means failure-aware recovery
    /// strictly beats ignoring the fault on every chaos cell — the
    /// `fault_recovery` default-flip gate.
    pub recovery_slo_delta_min: Option<f64>,
    /// The disaggregation section (empty unless `--disagg on` ran).
    pub disagg_cells: Vec<AbDisaggCell>,
    /// Minimum on−off SLO delta over matched length shapes: disagg
    /// must not buy its TTFT win with attainment (gate half 1).
    pub disagg_slo_delta_min: Option<f64>,
    /// Worst (maximum) on−off p99-TTFT delta over the same pairs:
    /// negative everywhere means disaggregation strictly cuts tail
    /// TTFT on every length shape (gate half 2). Together these gate
    /// the `disagg` default flip.
    pub disagg_ttft_delta_max: Option<f64>,
    /// The forecast sweep (empty unless `--sweep-forecast` ran).
    pub forecast_cells: Vec<AbForecastCell>,
}

fn round(x: f64, unit: f64) -> f64 {
    (x / unit).round() * unit
}

/// Markdown cell for a possibly-empty measurement: "-" instead of a
/// misleading number (or a NaN) when nothing was measured.
fn fmt_opt(x: Option<f64>, decimals: usize) -> String {
    match x {
        Some(v) => format!("{v:.decimals$}"),
        None => "-".to_string(),
    }
}

impl AbReport {
    /// The warm-start parity verdict: does warm-start hold SLO within
    /// [`WARM_PARITY_EPS`] of the cold search on every cell?
    pub fn warm_parity(&self) -> Option<bool> {
        self.warm_delta_min.map(|d| d >= -WARM_PARITY_EPS)
    }

    /// The comparison as a markdown table (one row per static baseline
    /// and per policy × warm cell). `include_timing` adds the
    /// wall-clock decision-latency columns, which are host-dependent —
    /// pass `false` for byte-reproducible output.
    pub fn to_markdown(&self, include_timing: bool) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "## ab: adaptation policies × scenarios ({}s, seed {}, \
             slo@{})",
            self.duration, self.seed, self.slo_scale
        );
        let timing_hdr = if include_timing {
            " decide-mean(ms) | decide-max(ms) |"
        } else {
            ""
        };
        let _ = writeln!(
            out,
            "| scenario | policy | warm | migration | slo | goodput | \
             p99(s) | migr | replans | downtime(s) | cost | kv-res | \
             done/arrived |{timing_hdr}"
        );
        let timing_sep = if include_timing { "---|---|" } else { "" };
        let _ = writeln!(
            out,
            "|---|---|---|---|---|---|---|---|---|---|---|---|---|\
             {timing_sep}"
        );
        for b in &self.baselines {
            let _ = writeln!(
                out,
                "| {} | static | - | - | {:.4} | {:.4} | {} | 0 | 0 | 0 \
                 | 0 | 0 | {}/{} |{}",
                b.shape,
                b.slo,
                b.goodput,
                fmt_opt(b.p99_latency, 3),
                b.completed,
                b.arrived,
                if include_timing { " - | - |" } else { "" }
            );
        }
        for c in &self.cells {
            let timing = if include_timing {
                format!(
                    " {:.2} | {:.2} |",
                    c.decision_ms_mean, c.decision_ms_max
                )
            } else {
                String::new()
            };
            let _ = writeln!(
                out,
                "| {} | {} | {} | {} | {:.4} | {:.4} | {} | {} | {} | \
                 {:.4} | {:.4} | {} | {}/{} |{}",
                c.shape,
                c.policy,
                if c.warm { "on" } else { "off" },
                c.migration,
                c.slo,
                c.goodput,
                fmt_opt(c.p99_latency, 3),
                c.migrations,
                c.replans,
                c.downtime_s,
                c.migration_cost,
                c.kv_resumed,
                c.completed,
                c.arrived,
                timing
            );
        }
        match (self.warm_delta_min, self.warm_parity()) {
            (Some(d), Some(ok)) => {
                let _ = writeln!(
                    out,
                    "\nwarm-start parity: min warm-cold slo delta \
                     {:.4} (eps {WARM_PARITY_EPS}) => {}",
                    d,
                    if ok {
                        "PARITY — warm-start is safe to default on"
                    } else {
                        "NO PARITY — keep the cold default"
                    }
                );
            }
            _ => {
                let _ = writeln!(
                    out,
                    "\nwarm-start parity: not measured (grid held no \
                     cold/warm pair)"
                );
            }
        }
        match (self.staged_downtime_delta_max, self.staged_slo_delta_min)
        {
            (Some(dt), Some(slo)) => {
                let _ = writeln!(
                    out,
                    "staged-vs-blackout: max downtime delta {dt:.4} \
                     LLM-s, min slo delta {slo:.4} => {}",
                    if dt < 0.0 && slo >= -WARM_PARITY_EPS {
                        "STAGED WINS — staged is safe to default on"
                    } else {
                        "NO WIN — keep the blackout default"
                    }
                );
            }
            _ => {
                let _ = writeln!(
                    out,
                    "staged-vs-blackout: not measured (grid held no \
                     blackout/staged pair)"
                );
            }
        }
        if !self.tier_cells.is_empty() {
            let _ = writeln!(
                out,
                "\n### tiered overload: fcfs vs tier-aware + shedding \
                 (identical streams)"
            );
            let _ = writeln!(
                out,
                "| scenario | mode | goodput | slo | shed(i/s/b) | \
                 g-int | g-std | g-bat | p99-int | p99-std | p99-bat | \
                 done/arrived |"
            );
            let _ = writeln!(
                out,
                "|---|---|---|---|---|---|---|---|---|---|---|---|"
            );
            for c in &self.tier_cells {
                let _ = writeln!(
                    out,
                    "| {} | {} | {:.4} | {:.4} | {}/{}/{} | {:.4} | \
                     {:.4} | {:.4} | {} | {} | {} | {}/{} |",
                    c.shape,
                    c.mode,
                    c.goodput,
                    c.slo,
                    c.shed[0],
                    c.shed[1],
                    c.shed[2],
                    c.tier_goodput[0],
                    c.tier_goodput[1],
                    c.tier_goodput[2],
                    fmt_opt(c.tier_p99[0], 3),
                    fmt_opt(c.tier_p99[1], 3),
                    fmt_opt(c.tier_p99[2], 3),
                    c.completed,
                    c.arrived,
                );
            }
            match self.shed_goodput_delta_min {
                Some(d) => {
                    let _ = writeln!(
                        out,
                        "\ntier-aware shedding: min tiered-fcfs goodput \
                         delta {d:.4} => {}",
                        if d > 0.0 {
                            "TIERED WINS — tier engine pays for itself \
                             under overload"
                        } else {
                            "NO WIN — keep the tier engine opt-in"
                        }
                    );
                }
                None => {
                    let _ = writeln!(
                        out,
                        "\ntier-aware shedding: not measured (no \
                         fcfs/tiered pair ran)"
                    );
                }
            }
        }
        if !self.fault_cells.is_empty() {
            let _ = writeln!(
                out,
                "\n### chaos: seeded faults, ignore vs failure-aware \
                 recovery (identical streams + schedules)"
            );
            let _ = writeln!(
                out,
                "| scenario | faults | mode | slo@arrived | lost | \
                 kv-rec | tok-recomp | mttr(s) | min-avail | \
                 done/arrived |"
            );
            let _ = writeln!(
                out,
                "|---|---|---|---|---|---|---|---|---|---|"
            );
            for c in &self.fault_cells {
                let _ = writeln!(
                    out,
                    "| {} | {} | {} | {:.4} | {} | {} | {} | {} | {} | \
                     {}/{} |",
                    c.shape,
                    c.faults,
                    c.mode,
                    c.slo,
                    c.lost,
                    c.kv_recovered,
                    c.tokens_recomputed,
                    fmt_opt(c.mttr_s, 3),
                    fmt_opt(c.availability_min, 4),
                    c.completed,
                    c.arrived,
                );
            }
            match self.recovery_slo_delta_min {
                Some(d) => {
                    let _ = writeln!(
                        out,
                        "\nfault recovery: min recover-ignore slo delta \
                         {d:.4} => {}",
                        if d > 0.0 {
                            "RECOVERY WINS — fault_recovery is safe to \
                             default on"
                        } else {
                            "NO WIN — keep fault_recovery opt-in"
                        }
                    );
                }
                None => {
                    let _ = writeln!(
                        out,
                        "\nfault recovery: not measured (no \
                         ignore/recover pair ran)"
                    );
                }
            }
        }
        if !self.disagg_cells.is_empty() {
            let _ = writeln!(
                out,
                "\n### disaggregation: mixed vs prefill/decode split + \
                 chunked prefill (identical streams)"
            );
            let _ = writeln!(
                out,
                "| scenario | disagg | slo | goodput | p99-ttft(s) | \
                 p99(s) | kv-res | done/arrived |"
            );
            let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
            for c in &self.disagg_cells {
                let _ = writeln!(
                    out,
                    "| {} | {} | {:.4} | {:.4} | {} | {} | {} | {}/{} |",
                    c.shape,
                    c.mode,
                    c.slo,
                    c.goodput,
                    fmt_opt(c.p99_ttft, 3),
                    fmt_opt(c.p99_latency, 3),
                    c.kv_resumed,
                    c.completed,
                    c.arrived,
                );
            }
            match (self.disagg_ttft_delta_max, self.disagg_slo_delta_min)
            {
                (Some(dt), Some(slo)) => {
                    let _ = writeln!(
                        out,
                        "\ndisagg-vs-mixed: max p99-ttft delta {dt:.4} \
                         s, min slo delta {slo:.4} => {}",
                        if dt < 0.0 && slo >= -WARM_PARITY_EPS {
                            "DISAGG WINS — disagg is safe to default on \
                             for long-context mixes"
                        } else {
                            "NO WIN — keep the mixed default"
                        }
                    );
                }
                _ => {
                    let _ = writeln!(
                        out,
                        "\ndisagg-vs-mixed: not measured (no off/on \
                         pair ran)"
                    );
                }
            }
        }
        if !self.forecast_cells.is_empty() {
            let _ = writeln!(
                out,
                "\n### forecast sweep: ForecastPolicy gain × horizon"
            );
            let _ = writeln!(
                out,
                "| scenario | gain | horizon | slo | goodput | p99(s) | \
                 replans | migr |"
            );
            let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
            for c in &self.forecast_cells {
                let _ = writeln!(
                    out,
                    "| {} | {:.2} | {:.2} | {:.4} | {:.4} | {} | {} | \
                     {} |",
                    c.shape,
                    c.gain,
                    c.horizon,
                    c.slo,
                    c.goodput,
                    fmt_opt(c.p99_latency, 3),
                    c.replans,
                    c.migrations,
                );
            }
        }
        out
    }

    /// The comparison in the AB_N.json schema. `include_timing` adds the
    /// host-dependent decision-latency fields; pass `false` for
    /// byte-reproducible output (the determinism test compares this).
    pub fn to_json(&self, include_timing: bool) -> Json {
        let mut cfg = BTreeMap::new();
        cfg.insert("duration_s".to_string(), Json::Num(self.duration));
        cfg.insert("seed".to_string(), Json::Num(self.seed as f64));
        cfg.insert("slo_scale".to_string(), Json::Num(self.slo_scale));

        let baselines: Vec<Json> = self
            .baselines
            .iter()
            .map(|b| {
                let mut m = BTreeMap::new();
                m.insert(
                    "shape".to_string(),
                    Json::Str(b.shape.to_string()),
                );
                m.insert(
                    "arrived".to_string(),
                    Json::Num(b.arrived as f64),
                );
                m.insert(
                    "completed".to_string(),
                    Json::Num(b.completed as f64),
                );
                m.insert("slo".to_string(), Json::Num(b.slo));
                m.insert("goodput".to_string(), Json::Num(b.goodput));
                m.insert(
                    "p99_latency_s".to_string(),
                    match b.p99_latency {
                        Some(p) => Json::Num(p),
                        None => Json::Null,
                    },
                );
                Json::Obj(m)
            })
            .collect();

        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert(
                    "shape".to_string(),
                    Json::Str(c.shape.to_string()),
                );
                m.insert(
                    "policy".to_string(),
                    Json::Str(c.policy.to_string()),
                );
                m.insert("warm".to_string(), Json::Bool(c.warm));
                m.insert(
                    "migration".to_string(),
                    Json::Str(c.migration.to_string()),
                );
                m.insert(
                    "arrived".to_string(),
                    Json::Num(c.arrived as f64),
                );
                m.insert(
                    "completed".to_string(),
                    Json::Num(c.completed as f64),
                );
                m.insert(
                    "dropped".to_string(),
                    Json::Num(c.dropped as f64),
                );
                m.insert("slo".to_string(), Json::Num(c.slo));
                m.insert("goodput".to_string(), Json::Num(c.goodput));
                m.insert(
                    "p99_latency_s".to_string(),
                    match c.p99_latency {
                        Some(p) => Json::Num(p),
                        None => Json::Null,
                    },
                );
                m.insert(
                    "replans".to_string(),
                    Json::Num(c.replans as f64),
                );
                m.insert(
                    "migrations".to_string(),
                    Json::Num(c.migrations as f64),
                );
                m.insert(
                    "downtime_s".to_string(),
                    Json::Num(c.downtime_s),
                );
                m.insert(
                    "migration_cost".to_string(),
                    Json::Num(c.migration_cost),
                );
                m.insert(
                    "kv_resumed".to_string(),
                    Json::Num(c.kv_resumed as f64),
                );
                if include_timing {
                    m.insert(
                        "decision_ms_mean".to_string(),
                        Json::Num(round(c.decision_ms_mean, 1e-3)),
                    );
                    m.insert(
                        "decision_ms_max".to_string(),
                        Json::Num(round(c.decision_ms_max, 1e-3)),
                    );
                }
                Json::Obj(m)
            })
            .collect();

        let tier_cells: Vec<Json> = self
            .tier_cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert(
                    "shape".to_string(),
                    Json::Str(c.shape.to_string()),
                );
                m.insert(
                    "mode".to_string(),
                    Json::Str(c.mode.to_string()),
                );
                m.insert(
                    "arrived".to_string(),
                    Json::Num(c.arrived as f64),
                );
                m.insert(
                    "completed".to_string(),
                    Json::Num(c.completed as f64),
                );
                m.insert("slo".to_string(), Json::Num(c.slo));
                m.insert("goodput".to_string(), Json::Num(c.goodput));
                for (i, tier) in SloClass::all().into_iter().enumerate()
                {
                    m.insert(
                        format!("shed_{}", tier.name()),
                        Json::Num(c.shed[i] as f64),
                    );
                    m.insert(
                        format!("goodput_{}", tier.name()),
                        Json::Num(c.tier_goodput[i]),
                    );
                    m.insert(
                        format!("p99_{}_s", tier.name()),
                        match c.tier_p99[i] {
                            Some(p) => Json::Num(p),
                            None => Json::Null,
                        },
                    );
                }
                Json::Obj(m)
            })
            .collect();

        let fault_cells: Vec<Json> = self
            .fault_cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert(
                    "shape".to_string(),
                    Json::Str(c.shape.to_string()),
                );
                m.insert(
                    "faults".to_string(),
                    Json::Str(c.faults.to_string()),
                );
                m.insert(
                    "mode".to_string(),
                    Json::Str(c.mode.to_string()),
                );
                m.insert(
                    "arrived".to_string(),
                    Json::Num(c.arrived as f64),
                );
                m.insert(
                    "completed".to_string(),
                    Json::Num(c.completed as f64),
                );
                m.insert("lost".to_string(), Json::Num(c.lost as f64));
                m.insert("slo".to_string(), Json::Num(c.slo));
                m.insert(
                    "kv_recovered".to_string(),
                    Json::Num(c.kv_recovered as f64),
                );
                m.insert(
                    "tokens_recomputed".to_string(),
                    Json::Num(c.tokens_recomputed as f64),
                );
                m.insert(
                    "mttr_s".to_string(),
                    match c.mttr_s {
                        Some(m) => Json::Num(m),
                        None => Json::Null,
                    },
                );
                m.insert(
                    "availability_min".to_string(),
                    match c.availability_min {
                        Some(a) => Json::Num(a),
                        None => Json::Null,
                    },
                );
                Json::Obj(m)
            })
            .collect();

        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("ab".to_string()));
        root.insert(
            "generator".to_string(),
            Json::Str(
                "muxserve ab --out AB_N.json (decision-latency fields \
                 are host-dependent; all other fields are deterministic \
                 in the config)"
                    .to_string(),
            ),
        );
        root.insert("config".to_string(), Json::Obj(cfg));
        root.insert("baselines".to_string(), Json::Arr(baselines));
        root.insert("cells".to_string(), Json::Arr(cells));
        root.insert("tier_cells".to_string(), Json::Arr(tier_cells));
        root.insert(
            "warm_delta_min".to_string(),
            match self.warm_delta_min {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        );
        root.insert(
            "warm_parity".to_string(),
            match self.warm_parity() {
                Some(ok) => Json::Bool(ok),
                None => Json::Null,
            },
        );
        root.insert(
            "warm_parity_eps".to_string(),
            Json::Num(WARM_PARITY_EPS),
        );
        root.insert(
            "staged_downtime_delta_max".to_string(),
            match self.staged_downtime_delta_max {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        );
        root.insert(
            "staged_slo_delta_min".to_string(),
            match self.staged_slo_delta_min {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        );
        root.insert(
            "shed_goodput_delta_min".to_string(),
            match self.shed_goodput_delta_min {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        );
        root.insert("fault_cells".to_string(), Json::Arr(fault_cells));
        root.insert(
            "recovery_slo_delta_min".to_string(),
            match self.recovery_slo_delta_min {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        );
        let disagg_cells: Vec<Json> = self
            .disagg_cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert(
                    "shape".to_string(),
                    Json::Str(c.shape.to_string()),
                );
                m.insert(
                    "disagg".to_string(),
                    Json::Str(c.mode.to_string()),
                );
                m.insert(
                    "arrived".to_string(),
                    Json::Num(c.arrived as f64),
                );
                m.insert(
                    "completed".to_string(),
                    Json::Num(c.completed as f64),
                );
                m.insert(
                    "dropped".to_string(),
                    Json::Num(c.dropped as f64),
                );
                m.insert("slo".to_string(), Json::Num(c.slo));
                m.insert("goodput".to_string(), Json::Num(c.goodput));
                m.insert(
                    "p99_ttft_s".to_string(),
                    match c.p99_ttft {
                        Some(p) => Json::Num(p),
                        None => Json::Null,
                    },
                );
                m.insert(
                    "p99_latency_s".to_string(),
                    match c.p99_latency {
                        Some(p) => Json::Num(p),
                        None => Json::Null,
                    },
                );
                m.insert(
                    "kv_resumed".to_string(),
                    Json::Num(c.kv_resumed as f64),
                );
                Json::Obj(m)
            })
            .collect();
        root.insert("disagg_cells".to_string(), Json::Arr(disagg_cells));
        root.insert(
            "disagg_slo_delta_min".to_string(),
            match self.disagg_slo_delta_min {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        );
        root.insert(
            "disagg_ttft_delta_max".to_string(),
            match self.disagg_ttft_delta_max {
                Some(d) => Json::Num(d),
                None => Json::Null,
            },
        );
        let forecast_cells: Vec<Json> = self
            .forecast_cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert(
                    "shape".to_string(),
                    Json::Str(c.shape.to_string()),
                );
                m.insert("gain".to_string(), Json::Num(c.gain));
                m.insert("horizon".to_string(), Json::Num(c.horizon));
                m.insert("slo".to_string(), Json::Num(c.slo));
                m.insert("goodput".to_string(), Json::Num(c.goodput));
                m.insert(
                    "p99_latency_s".to_string(),
                    match c.p99_latency {
                        Some(p) => Json::Num(p),
                        None => Json::Null,
                    },
                );
                m.insert(
                    "replans".to_string(),
                    Json::Num(c.replans as f64),
                );
                m.insert(
                    "migrations".to_string(),
                    Json::Num(c.migrations as f64),
                );
                Json::Obj(m)
            })
            .collect();
        root.insert(
            "forecast_cells".to_string(),
            Json::Arr(forecast_cells),
        );
        Json::Obj(root)
    }
}

/// Minimum warm−cold SLO delta over matched (shape, policy, migration)
/// pairs. Pairs where either side completed nothing are skipped: an
/// empty cell's attainment is vacuous (0 over 0 requests), and pairing
/// it would manufacture a ±1.0 "delta" out of no evidence at all —
/// enough to flip the parity verdict on its own.
fn warm_delta_min(cells: &[AbCell]) -> Option<f64> {
    let mut min: Option<f64> = None;
    for w in cells.iter().filter(|c| c.warm && c.completed > 0) {
        let cold = cells.iter().find(|c| {
            !c.warm
                && c.completed > 0
                && c.shape == w.shape
                && c.policy == w.policy
                && c.migration == w.migration
        });
        if let Some(cold) = cold {
            let d = w.slo - cold.slo;
            min = Some(match min {
                Some(m) => m.min(d),
                None => d,
            });
        }
    }
    min
}

/// Staged−blackout deltas over matched (shape, policy, warm) pairs:
/// (max downtime delta, min SLO delta). Empty cells are skipped for the
/// same reason as in [`warm_delta_min`].
fn staged_deltas(cells: &[AbCell]) -> (Option<f64>, Option<f64>) {
    let mut dt_max: Option<f64> = None;
    let mut slo_min: Option<f64> = None;
    for s in cells
        .iter()
        .filter(|c| c.migration == "staged" && c.completed > 0)
    {
        let b = cells.iter().find(|c| {
            c.migration == "blackout"
                && c.completed > 0
                && c.shape == s.shape
                && c.policy == s.policy
                && c.warm == s.warm
        });
        if let Some(b) = b {
            let dt = s.downtime_s - b.downtime_s;
            let slo = s.slo - b.slo;
            dt_max = Some(dt_max.map_or(dt, |m: f64| m.max(dt)));
            slo_min = Some(slo_min.map_or(slo, |m: f64| m.min(slo)));
        }
    }
    (dt_max, slo_min)
}

/// Minimum tiered−fcfs goodput delta over matched overload shapes.
fn shed_goodput_delta_min(cells: &[AbTierCell]) -> Option<f64> {
    let mut min: Option<f64> = None;
    for t in cells.iter().filter(|c| c.mode == "tiered") {
        let base = cells
            .iter()
            .find(|c| c.mode == "fcfs" && c.shape == t.shape);
        if let Some(base) = base {
            let d = t.goodput - base.goodput;
            min = Some(match min {
                Some(m) => m.min(d),
                None => d,
            });
        }
    }
    min
}

/// Minimum recover−ignore SLO delta over matched (shape, faults)
/// chaos pairs. Unlike [`warm_delta_min`], empty cells are NOT
/// skipped: fault cells score over arrivals, so a run that completed
/// nothing is genuine evidence (everything was lost), not a vacuous
/// ratio.
fn recovery_slo_delta_min(cells: &[AbFaultCell]) -> Option<f64> {
    let mut min: Option<f64> = None;
    for r in cells.iter().filter(|c| c.mode == "recover") {
        let base = cells.iter().find(|c| {
            c.mode == "ignore"
                && c.shape == r.shape
                && c.faults == r.faults
        });
        if let Some(base) = base {
            let d = r.slo - base.slo;
            min = Some(match min {
                Some(m) => m.min(d),
                None => d,
            });
        }
    }
    min
}

/// Disagg on−off deltas over matched length shapes: (min SLO delta,
/// max p99-TTFT delta). Pairs where either side completed nothing are
/// skipped, as in [`warm_delta_min`]; pairs where either side measured
/// no TTFT contribute to the SLO delta only.
fn disagg_deltas(cells: &[AbDisaggCell]) -> (Option<f64>, Option<f64>) {
    let mut slo_min: Option<f64> = None;
    let mut ttft_max: Option<f64> = None;
    for on in cells.iter().filter(|c| c.mode == "on" && c.completed > 0)
    {
        let off = cells.iter().find(|c| {
            c.mode == "off" && c.completed > 0 && c.shape == on.shape
        });
        if let Some(off) = off {
            let slo = on.slo - off.slo;
            slo_min = Some(slo_min.map_or(slo, |m: f64| m.min(slo)));
            if let (Some(a), Some(b)) = (on.p99_ttft, off.p99_ttft) {
                let dt = a - b;
                ttft_max =
                    Some(ttft_max.map_or(dt, |m: f64| m.max(dt)));
            }
        }
    }
    (slo_min, ttft_max)
}

/// Run the whole grid. Scenarios that admit no initial placement are
/// skipped (none of the built-in shapes do on the default cluster).
pub fn run_ab(cfg: &AbConfig) -> AbReport {
    let cluster = scenario_cluster();
    let engine = EngineConfig {
        eviction: cfg.eviction,
        host_tier_blocks: cfg.host_tier_blocks,
        ..EngineConfig::muxserve()
    };
    let mut baselines = Vec::new();
    let mut cells = Vec::new();
    for &shape in &cfg.shapes {
        let scenario = Scenario {
            duration: cfg.duration,
            seed: cfg.seed,
            ..Scenario::new(shape)
        };
        // One materialization per shape: every mode below replays the
        // exact same request stream.
        let data = scenario.build();
        let arrived = data.requests.len();
        if let Some(report) =
            run_scenario_cfg(&scenario, &data, &cluster, engine, None)
        {
            baselines.push(AbBaseline {
                shape: shape.name(),
                arrived,
                completed: report.eval.records.len(),
                slo: round(report.eval.slo_attainment(cfg.slo_scale), 1e-4),
                goodput: round(report.eval.goodput(cfg.slo_scale), 1e-4),
                p99_latency: report
                    .eval
                    .latency_summary()
                    .try_p99()
                    .map(|p| round(p, 1e-3)),
            });
        }
        for &policy in &cfg.policies {
            for &warm in &cfg.warm_modes {
                for &migration_mode in &cfg.migration_modes {
                    let rcfg = ReplanConfig {
                        policy,
                        warm_start: warm,
                        migration_mode,
                        ..Default::default()
                    };
                    let Some(report) = run_scenario_cfg(
                        &scenario,
                        &data,
                        &cluster,
                        engine,
                        Some(rcfg),
                    ) else {
                        continue;
                    };
                    let fired = report.replans.len();
                    let (mean_ms, max_ms) = if fired > 0 {
                        let sum: f64 = report
                            .replans
                            .iter()
                            .map(|r| r.decision_ms)
                            .sum();
                        let max = report
                            .replans
                            .iter()
                            .map(|r| r.decision_ms)
                            .fold(0.0_f64, f64::max);
                        (sum / fired as f64, max)
                    } else {
                        (0.0, 0.0)
                    };
                    cells.push(AbCell {
                        shape: shape.name(),
                        policy: policy.name(),
                        warm,
                        migration: migration_mode.name(),
                        arrived,
                        completed: report.eval.records.len(),
                        dropped: report.dropped,
                        slo: round(
                            report.eval.slo_attainment(cfg.slo_scale),
                            1e-4,
                        ),
                        goodput: round(
                            report.eval.goodput(cfg.slo_scale),
                            1e-4,
                        ),
                        p99_latency: report
                            .eval
                            .latency_summary()
                            .try_p99()
                            .map(|p| round(p, 1e-3)),
                        replans: fired,
                        migrations: report.migrations,
                        downtime_s: round(report.downtime_s, 1e-4),
                        migration_cost: round(
                            report.migration_cost,
                            1e-4,
                        ),
                        kv_resumed: report.kv_resumed,
                        decision_ms_mean: mean_ms,
                        decision_ms_max: max_ms,
                    });
                }
            }
        }
    }
    // The tiered-overload section: static runs (no replanning) so the
    // delta is attributable to the tier engine alone, tier-blind FCFS
    // admission vs slack-ordered scheduling + load shedding.
    let mut tier_cells = Vec::new();
    for &shape in &cfg.overload_shapes {
        let scenario = Scenario {
            duration: cfg.duration,
            seed: cfg.seed,
            ..Scenario::new(shape)
        };
        let data = scenario.build();
        let arrived = data.requests.len();
        for (mode, tier_aware, shed) in
            [("fcfs", false, false), ("tiered", true, true)]
        {
            let eng = EngineConfig { tier_aware, shed, ..engine };
            let Some(report) =
                run_scenario_cfg(&scenario, &data, &cluster, eng, None)
            else {
                continue;
            };
            let eval = &report.eval;
            let mut tier_goodput = [0.0; 3];
            let mut tier_p99 = [None; 3];
            for (i, tier) in SloClass::all().into_iter().enumerate() {
                tier_goodput[i] =
                    round(eval.tier_goodput(cfg.slo_scale, tier), 1e-4);
                tier_p99[i] =
                    eval.tier_p99_latency(tier).map(|p| round(p, 1e-3));
            }
            tier_cells.push(AbTierCell {
                shape: shape.name(),
                mode,
                arrived,
                completed: eval.records.len(),
                shed: report.shed,
                goodput: round(eval.goodput(cfg.slo_scale), 1e-4),
                slo: round(eval.slo_attainment(cfg.slo_scale), 1e-4),
                tier_goodput,
                tier_p99,
            });
        }
    }
    // The chaos section: each (shape, fault axis) pair runs the same
    // stream under the same seeded fault schedule twice, differing in
    // nothing but `fault_recovery`. The replan check period sits past
    // the horizon so no periodic replan fires — the emergency path is
    // the only thing the recover arm adds.
    let mut fault_cells = Vec::new();
    for &shape in &cfg.shapes {
        let scenario = Scenario {
            duration: cfg.duration,
            seed: cfg.seed,
            ..Scenario::new(shape)
        };
        let data = scenario.build();
        let arrived = data.requests.len();
        for &axis in &cfg.faults {
            if axis == FaultsAxis::None {
                continue;
            }
            for (mode, recover) in [("ignore", false), ("recover", true)]
            {
                let rcfg = ReplanConfig {
                    check_period: cfg.duration + 1.0,
                    migration_mode: MigrationMode::Staged,
                    fault_recovery: recover,
                    ..Default::default()
                };
                let Some(report) = run_scenario_faults(
                    &scenario,
                    &data,
                    &cluster,
                    engine,
                    Some(rcfg),
                    axis,
                ) else {
                    continue;
                };
                let completed = report.eval.records.len();
                let slo = if arrived > 0 {
                    report.eval.slo_attainment(cfg.slo_scale)
                        * completed as f64
                        / arrived as f64
                } else {
                    0.0
                };
                let f = &report.fault;
                fault_cells.push(AbFaultCell {
                    shape: shape.name(),
                    faults: axis.name(),
                    mode,
                    arrived,
                    completed,
                    lost: f.lost_requests,
                    slo: round(slo, 1e-4),
                    kv_recovered: f.kv_recovered,
                    tokens_recomputed: f.tokens_recomputed,
                    mttr_s: f.mttr_s.map(|m| round(m, 1e-4)),
                    availability_min: f
                        .availability
                        .iter()
                        .copied()
                        .reduce(f64::min)
                        .map(|a| round(a, 1e-4)),
                });
            }
        }
    }
    // The disaggregation section: each length shape runs the identical
    // stream twice — the default mixed engine vs phase-role placement
    // + chunked prefill. The on arm's replan check period sits past the
    // horizon, so the tiered placement is computed once at t=0 and the
    // delta is attributable to disaggregation alone.
    let mut disagg_cells = Vec::new();
    if cfg.disagg {
        for &shape in &cfg.length_shapes {
            let scenario = Scenario {
                duration: cfg.duration,
                seed: cfg.seed,
                ..Scenario::new(shape)
            };
            let data = scenario.build();
            let arrived = data.requests.len();
            for (mode, on) in [("off", false), ("on", true)] {
                let eng = EngineConfig {
                    chunk_prefill_tokens: if on {
                        cfg.chunk_prefill_tokens
                    } else {
                        0
                    },
                    ..engine
                };
                let rcfg = on.then(|| ReplanConfig {
                    check_period: cfg.duration + 1.0,
                    disagg: true,
                    ..Default::default()
                });
                let Some(report) = run_scenario_cfg(
                    &scenario,
                    &data,
                    &cluster,
                    eng,
                    rcfg,
                ) else {
                    continue;
                };
                let eval = &report.eval;
                disagg_cells.push(AbDisaggCell {
                    shape: shape.name(),
                    mode,
                    arrived,
                    completed: eval.records.len(),
                    dropped: report.dropped,
                    slo: round(eval.slo_attainment(cfg.slo_scale), 1e-4),
                    goodput: round(eval.goodput(cfg.slo_scale), 1e-4),
                    p99_ttft: eval
                        .ttft_summary()
                        .try_p99()
                        .map(|p| round(p, 1e-3)),
                    p99_latency: eval
                        .latency_summary()
                        .try_p99()
                        .map(|p| round(p, 1e-3)),
                    kv_resumed: report.kv_resumed,
                });
            }
        }
    }
    // The forecast sweep: ForecastPolicy alone, its two knobs gridded
    // over the forecastable shapes (the ones with a trend to chase).
    let mut forecast_cells = Vec::new();
    if cfg.sweep_forecast {
        for shape in [ScenarioShape::FlashCrowd, ScenarioShape::Drift] {
            let scenario = Scenario {
                duration: cfg.duration,
                seed: cfg.seed,
                ..Scenario::new(shape)
            };
            let data = scenario.build();
            for gain in [0.25, 0.5, 1.0] {
                for horizon in [1.0, 2.0, 4.0] {
                    let rcfg = ReplanConfig {
                        policy: PolicyKind::Forecast,
                        forecast_gain: gain,
                        forecast_horizon: horizon,
                        ..Default::default()
                    };
                    let Some(report) = run_scenario_cfg(
                        &scenario,
                        &data,
                        &cluster,
                        engine,
                        Some(rcfg),
                    ) else {
                        continue;
                    };
                    let eval = &report.eval;
                    forecast_cells.push(AbForecastCell {
                        shape: shape.name(),
                        gain,
                        horizon,
                        slo: round(
                            eval.slo_attainment(cfg.slo_scale),
                            1e-4,
                        ),
                        goodput: round(eval.goodput(cfg.slo_scale), 1e-4),
                        p99_latency: eval
                            .latency_summary()
                            .try_p99()
                            .map(|p| round(p, 1e-3)),
                        replans: report.replans.len(),
                        migrations: report.migrations,
                    });
                }
            }
        }
    }
    let warm_delta = warm_delta_min(&cells);
    let (staged_dt, staged_slo) = staged_deltas(&cells);
    let shed_delta = shed_goodput_delta_min(&tier_cells);
    let recovery_delta = recovery_slo_delta_min(&fault_cells);
    let (disagg_slo, disagg_ttft) = disagg_deltas(&disagg_cells);
    AbReport {
        duration: cfg.duration,
        seed: cfg.seed,
        slo_scale: cfg.slo_scale,
        baselines,
        cells,
        tier_cells,
        warm_delta_min: warm_delta,
        staged_downtime_delta_max: staged_dt,
        staged_slo_delta_min: staged_slo,
        shed_goodput_delta_min: shed_delta,
        fault_cells,
        recovery_slo_delta_min: recovery_delta,
        disagg_cells,
        disagg_slo_delta_min: disagg_slo,
        disagg_ttft_delta_max: disagg_ttft,
        forecast_cells,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ab_comparison_is_deterministic_and_covers_the_grid() {
        // A reduced grid keeps the test fast while still crossing two
        // policies, two scenarios, both warm modes, and both migration
        // executors.
        let cfg = AbConfig {
            duration: 40.0,
            shapes: vec![ScenarioShape::FlashCrowd, ScenarioShape::Drift],
            overload_shapes: vec![ScenarioShape::Overcommit],
            policies: vec![PolicyKind::Threshold, PolicyKind::Forecast],
            warm_modes: vec![false, true],
            migration_modes: MigrationMode::all().to_vec(),
            faults: vec![FaultsAxis::None, FaultsAxis::SingleUnit],
            ..AbConfig::smoke()
        };
        let a = run_ab(&cfg);
        let b = run_ab(&cfg);
        assert_eq!(
            a.to_json(false).to_string(),
            b.to_json(false).to_string(),
            "same seed must give a byte-identical comparison"
        );
        assert_eq!(a.to_markdown(false), b.to_markdown(false));
        // Full grid: every policy × shape × warm × migration cell plus
        // a baseline row per shape.
        assert_eq!(a.cells.len(), 2 * 2 * 2 * 2, "cells: {:?}", a.cells);
        assert_eq!(a.baselines.len(), 2);
        // The tier section ran its overload shape in both modes.
        assert_eq!(a.tier_cells.len(), 2, "tier: {:?}", a.tier_cells);
        // The chaos section ran each shape under the one real axis in
        // both arms; the None axis injected nothing and added no cells.
        assert_eq!(a.fault_cells.len(), 4, "fault: {:?}", a.fault_cells);
        // The verdicts are measured, whichever way they land.
        assert!(a.warm_delta_min.is_some());
        assert!(a.warm_parity().is_some());
        assert!(a.staged_downtime_delta_max.is_some());
        assert!(a.staged_slo_delta_min.is_some());
        assert!(a.shed_goodput_delta_min.is_some());
        assert!(a.recovery_slo_delta_min.is_some());
    }

    fn mk_cell(
        shape: &'static str,
        policy: &'static str,
        warm: bool,
        migration: &'static str,
        slo: f64,
        downtime_s: f64,
    ) -> AbCell {
        AbCell {
            shape,
            policy,
            warm,
            migration,
            arrived: 100,
            completed: 90,
            dropped: 0,
            slo,
            goodput: 1.0,
            p99_latency: Some(1.0),
            replans: 1,
            migrations: 1,
            downtime_s,
            migration_cost: 10.0,
            kv_resumed: 0,
            decision_ms_mean: 0.0,
            decision_ms_max: 0.0,
        }
    }

    #[test]
    fn warm_delta_min_matches_hand_computation() {
        let mk = |shape, policy, warm, slo| {
            mk_cell(shape, policy, warm, "blackout", slo, 6.0)
        };
        let cells = vec![
            mk("flash-crowd", "threshold", false, 0.90),
            mk("flash-crowd", "threshold", true, 0.88),
            mk("drift", "threshold", false, 0.70),
            mk("drift", "threshold", true, 0.75),
        ];
        let d = warm_delta_min(&cells).expect("two matched pairs");
        assert!((d - (-0.02)).abs() < 1e-12, "d={d}");
        // A cell with no matching cold twin contributes nothing.
        assert!(warm_delta_min(&cells[1..2]).is_none());
        // Cells in different migration modes never pair up.
        let cross = vec![
            mk_cell("drift", "threshold", false, "blackout", 0.7, 6.0),
            mk_cell("drift", "threshold", true, "staged", 0.9, 1.0),
        ];
        assert!(warm_delta_min(&cross).is_none());
    }

    #[test]
    fn staged_deltas_match_hand_computation() {
        let cells = vec![
            mk_cell("flash-crowd", "threshold", false, "blackout", 0.80, 6.0),
            mk_cell("flash-crowd", "threshold", false, "staged", 0.85, 1.5),
            mk_cell("drift", "threshold", false, "blackout", 0.70, 12.0),
            mk_cell("drift", "threshold", false, "staged", 0.69, 2.0),
        ];
        let (dt, slo) = staged_deltas(&cells);
        // Worst downtime delta: max(1.5-6.0, 2.0-12.0) = -4.5.
        assert!((dt.unwrap() - (-4.5)).abs() < 1e-12, "dt={dt:?}");
        // Worst SLO delta: min(0.05, -0.01) = -0.01.
        assert!((slo.unwrap() - (-0.01)).abs() < 1e-12, "slo={slo:?}");
        // Unpaired staged cells contribute nothing.
        let (dt2, slo2) = staged_deltas(&cells[1..2]);
        assert!(dt2.is_none() && slo2.is_none());
    }

    #[test]
    fn empty_cells_never_poison_the_verdicts() {
        // A run that completes nothing has no attainment to speak of:
        // its slo reads 0.0 and its p99 is None. Before these cells
        // were skipped, pairing one manufactured a -0.90 "delta" out
        // of zero evidence and flipped the parity verdict.
        let mut empty_warm =
            mk_cell("drift", "threshold", true, "blackout", 0.0, 6.0);
        empty_warm.completed = 0;
        empty_warm.p99_latency = None;
        let cells = vec![
            mk_cell("drift", "threshold", false, "blackout", 0.90, 6.0),
            empty_warm.clone(),
            mk_cell("flash-crowd", "forecast", false, "blackout", 0.80, 6.0),
            mk_cell("flash-crowd", "forecast", true, "blackout", 0.79, 6.0),
        ];
        // Only the flash-crowd pair counts: delta -0.01, not -0.90.
        let d = warm_delta_min(&cells).expect("one live pair");
        assert!((d - (-0.01)).abs() < 1e-12, "d={d}");

        // Same guard on the staged/blackout pairing.
        let mut empty_staged =
            mk_cell("drift", "threshold", false, "staged", 0.0, 0.5);
        empty_staged.completed = 0;
        let cells = vec![
            mk_cell("drift", "threshold", false, "blackout", 0.90, 6.0),
            empty_staged,
        ];
        let (dt, slo) = staged_deltas(&cells);
        assert!(dt.is_none() && slo.is_none());

        // And empty cells render as "-", not "NaN", in markdown.
        let report = AbReport {
            duration: 1.0,
            seed: 1,
            slo_scale: 8.0,
            baselines: vec![],
            cells: vec![empty_warm],
            tier_cells: vec![],
            warm_delta_min: None,
            staged_downtime_delta_max: None,
            staged_slo_delta_min: None,
            shed_goodput_delta_min: None,
            fault_cells: vec![],
            recovery_slo_delta_min: None,
            disagg_cells: vec![],
            disagg_slo_delta_min: None,
            disagg_ttft_delta_max: None,
            forecast_cells: vec![],
        };
        let md = report.to_markdown(false);
        assert!(!md.contains("NaN"), "markdown leaked a NaN:\n{md}");
        let js = report.to_json(false).to_string();
        assert!(!js.contains("NaN"), "json leaked a NaN:\n{js}");
        assert!(js.contains("\"p99_latency_s\":null"), "{js}");
    }

    #[test]
    fn recovery_slo_delta_matches_hand_computation() {
        let mk = |shape, faults, mode, slo| AbFaultCell {
            shape,
            faults,
            mode,
            arrived: 100,
            completed: 80,
            lost: 20,
            slo,
            kv_recovered: 3,
            tokens_recomputed: 640,
            mttr_s: Some(4.0),
            availability_min: Some(0.9),
        };
        let cells = vec![
            mk("drift", "single-unit", "ignore", 0.50),
            mk("drift", "single-unit", "recover", 0.80),
            mk("drift", "rolling", "ignore", 0.40),
            mk("drift", "rolling", "recover", 0.45),
        ];
        // min(0.80-0.50, 0.45-0.40) = 0.05.
        let d = recovery_slo_delta_min(&cells).expect("two pairs");
        assert!((d - 0.05).abs() < 1e-12, "d={d}");
        // An unpaired recover cell contributes nothing.
        assert!(recovery_slo_delta_min(&cells[1..2]).is_none());
        // Unlike the warm/staged verdicts, an empty cell still pairs:
        // completing nothing under faults is evidence, not a vacuous
        // ratio.
        let mut dead = mk("drift", "single-unit", "ignore", 0.0);
        dead.completed = 0;
        let cells =
            vec![dead, mk("drift", "single-unit", "recover", 0.7)];
        let d = recovery_slo_delta_min(&cells).expect("pair");
        assert!((d - 0.7).abs() < 1e-12, "d={d}");
    }

    #[test]
    fn disagg_deltas_match_hand_computation() {
        let mk = |shape, mode, slo, ttft: Option<f64>| AbDisaggCell {
            shape,
            mode,
            arrived: 100,
            completed: 90,
            dropped: 0,
            slo,
            goodput: 1.0,
            p99_ttft: ttft,
            p99_latency: Some(2.0),
            kv_resumed: if mode == "on" { 5 } else { 0 },
        };
        let cells = vec![
            mk("bimodal-long", "off", 0.80, Some(3.0)),
            mk("bimodal-long", "on", 0.82, Some(1.0)),
            mk("length-drift", "off", 0.70, Some(4.0)),
            mk("length-drift", "on", 0.69, Some(2.5)),
        ];
        let (slo, ttft) = disagg_deltas(&cells);
        // min(0.02, -0.01) = -0.01; max(1.0-3.0, 2.5-4.0) = -1.5.
        assert!((slo.unwrap() - (-0.01)).abs() < 1e-12, "slo={slo:?}");
        assert!((ttft.unwrap() - (-1.5)).abs() < 1e-12, "ttft={ttft:?}");
        // Unpaired on-cells contribute nothing.
        let (s2, t2) = disagg_deltas(&cells[1..2]);
        assert!(s2.is_none() && t2.is_none());
        // An empty cell never pairs (vacuous attainment).
        let mut dead = mk("bimodal-long", "off", 0.0, None);
        dead.completed = 0;
        let (s3, t3) = disagg_deltas(&[
            dead,
            mk("bimodal-long", "on", 0.9, Some(1.0)),
        ]);
        assert!(s3.is_none() && t3.is_none());
        // A pair without TTFT on one side still scores SLO.
        let (s4, t4) = disagg_deltas(&[
            mk("bimodal-long", "off", 0.8, None),
            mk("bimodal-long", "on", 0.9, Some(1.0)),
        ]);
        assert!((s4.unwrap() - 0.1).abs() < 1e-12);
        assert!(t4.is_none());
    }

    #[test]
    fn disagg_section_is_deterministic_and_opt_in() {
        // Off by default: no disagg cells, verdicts unmeasured.
        let base = AbConfig {
            duration: 30.0,
            shapes: vec![],
            overload_shapes: vec![],
            policies: vec![],
            ..AbConfig::smoke()
        };
        let plain = run_ab(&base);
        assert!(plain.disagg_cells.is_empty());
        assert!(plain.disagg_slo_delta_min.is_none());
        assert!(plain.forecast_cells.is_empty());

        // Opted in: both arms run per length shape, byte-identically
        // across invocations, and the verdict pair is measured.
        let cfg = AbConfig {
            disagg: true,
            length_shapes: vec![ScenarioShape::BimodalLong],
            ..base
        };
        let a = run_ab(&cfg);
        let b = run_ab(&cfg);
        assert_eq!(
            a.to_json(false).to_string(),
            b.to_json(false).to_string()
        );
        assert_eq!(a.to_markdown(false), b.to_markdown(false));
        assert_eq!(a.disagg_cells.len(), 2, "{:?}", a.disagg_cells);
        assert_eq!(a.disagg_cells[0].mode, "off");
        assert_eq!(a.disagg_cells[1].mode, "on");
        // The off arm never touches the handoff machinery.
        assert_eq!(a.disagg_cells[0].kv_resumed, 0);
        assert!(a.disagg_slo_delta_min.is_some());
        assert!(a.disagg_ttft_delta_max.is_some());
        let md = a.to_markdown(false);
        assert!(md.contains("disagg-vs-mixed"), "{md}");
    }

    #[test]
    fn shed_goodput_delta_matches_hand_computation() {
        let mk = |shape, mode, goodput| AbTierCell {
            shape,
            mode,
            arrived: 100,
            completed: 80,
            shed: [0, 0, 20],
            goodput,
            slo: 0.9,
            tier_goodput: [goodput / 2.0, goodput / 4.0, goodput / 4.0],
            tier_p99: [Some(1.0), Some(2.0), None],
        };
        let cells = vec![
            mk("overcommit", "fcfs", 2.0),
            mk("overcommit", "tiered", 3.0),
            mk("flash-overload", "fcfs", 1.0),
            mk("flash-overload", "tiered", 1.2),
        ];
        let d = shed_goodput_delta_min(&cells).expect("two pairs");
        // min(3.0-2.0, 1.2-1.0) = 0.2.
        assert!((d - 0.2).abs() < 1e-12, "d={d}");
        // An unpaired tiered cell contributes nothing.
        assert!(shed_goodput_delta_min(&cells[1..2]).is_none());
    }
}
