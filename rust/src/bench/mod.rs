//! Figure/table regeneration harnesses (filled in per DESIGN.md §4).

pub mod experiments;
pub mod figures;

pub use experiments::*;
