//! Figure/table regeneration harnesses (filled in per DESIGN.md §4),
//! the drift figure for the dynamic-workload scenarios, the
//! `bench-perf` event-core performance baseline, the `ab`
//! adaptation-policy A/B harness, and the `bench-cache` KV cache-layer
//! figure.

pub mod ab;
pub mod cache;
pub mod drift;
pub mod experiments;
pub mod figures;
pub mod perf;

pub use ab::{run_ab, AbConfig, AbReport, WARM_PARITY_EPS};
pub use cache::{run_bench_cache, CacheCell, CacheConfig, CacheReport};
pub use drift::{
    fig_drift, run_scenario, run_scenario_cfg, run_scenario_faults,
    run_scenario_on, run_trace, run_trace_faults, scenario_cluster,
    ScenarioResult,
};
pub use experiments::*;
pub use perf::{
    dynamic_fingerprint, run_bench_perf, PerfConfig, PerfReport, ShardPerf,
};
