//! Figure/table regeneration harnesses (filled in per DESIGN.md §4),
//! the drift figure for the dynamic-workload scenarios, and the
//! `bench-perf` event-core performance baseline.

pub mod drift;
pub mod experiments;
pub mod figures;
pub mod perf;

pub use drift::{
    fig_drift, run_scenario, run_scenario_on, run_trace, scenario_cluster,
    ScenarioResult,
};
pub use experiments::*;
pub use perf::{run_bench_perf, PerfConfig, PerfReport};
