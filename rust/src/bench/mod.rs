//! Figure/table regeneration harnesses (filled in per DESIGN.md §4),
//! plus the drift figure for the dynamic-workload scenarios.

pub mod drift;
pub mod experiments;
pub mod figures;

pub use drift::{
    fig_drift, run_scenario, run_scenario_on, run_trace, scenario_cluster,
    ScenarioResult,
};
pub use experiments::*;
