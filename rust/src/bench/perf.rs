//! `bench-perf`: the event-core performance baseline (the BENCH_N.json
//! trajectory; BENCH_3.json is the first committed point).
//!
//! Runs the paper-scale setting — the 19-LLM synthetic zoo (§4.2,
//! Table 1) on the 4×8 A100 testbed — through three hot paths:
//!
//! 1. **Static event loop**: cold placement + a stationary Poisson replay,
//!    reporting wall-clock and events/sec (the simulator-core metric the
//!    id-index work optimizes).
//! 2. **Dynamic flash-crowd**: the online re-placement loop armed, with
//!    the warm-started optimizer, over the same duration.
//! 3. **Replan decision latency**: the from-scratch optimizer vs. the
//!    warm start on one drifted rate vector (a locally absorbable sag —
//!    the warm fast path), plus the hopeless-spike case where warm-start
//!    must fall back to the full search.
//!
//! `--smoke` shrinks everything to a 6-LLM / 4-GPU config that finishes
//! in seconds — the CI gross-regression tripwire (`--max-wall`), not a
//! micro-benchmark.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::config::{synthetic_zoo, ClusterSpec, ModelSpec};
use crate::coordinator::estimator::Estimator;
use crate::coordinator::migration::MigrationMode;
use crate::coordinator::{
    muxserve_placement, muxserve_placement_cached, muxserve_placement_warm,
    muxserve_placement_warm_cached, EngineConfig, PlacementCache,
    ReplanConfig,
};
use crate::costmodel::CostModel;
use crate::simulator::{DynamicReport, DynamicSimulation, Simulation};
use crate::util::json::Json;
use crate::workload::{synthetic_workload, Scenario, ScenarioShape};

/// Knobs of one `bench-perf` run.
#[derive(Clone, Debug)]
pub struct PerfConfig {
    /// Simulated seconds per scenario run.
    pub duration: f64,
    /// Repetitions for the replan-latency timings (min is reported).
    pub reps: u32,
    /// Smoke mode: 6 LLMs / 4 GPUs instead of 19 / 32.
    pub smoke: bool,
    /// Worker shards for the dynamic runs (1 = the serial loop). Only
    /// wall-clock numbers may move with this knob — every simulated
    /// quantity is shard-count-invariant (the determinism contract CI
    /// checks by diffing `--strip-timing` output across shard counts).
    pub shards: usize,
}

impl PerfConfig {
    /// The paper-scale baseline configuration.
    pub fn full() -> Self {
        PerfConfig { duration: 120.0, reps: 3, smoke: false, shards: 1 }
    }

    /// The CI tripwire configuration.
    pub fn smoke() -> Self {
        PerfConfig { duration: 20.0, reps: 1, smoke: true, shards: 1 }
    }
}

/// One simulated run's throughput numbers.
#[derive(Clone, Debug)]
pub struct SimPerf {
    pub label: &'static str,
    pub requests: usize,
    pub completed: usize,
    pub events: u64,
    pub wall_s: f64,
    pub events_per_s: f64,
}

/// One point of the shard-scaling sweep: the stationary replay driven
/// through the *dynamic* engine (adapt ticks + replan barriers armed)
/// at a given worker-shard count.
#[derive(Clone, Debug)]
pub struct ShardPerf {
    pub shards: usize,
    pub events: u64,
    pub wall_s: f64,
    pub events_per_s: f64,
    /// `events_per_s` relative to the serial (`shards == 1`) row.
    pub speedup: f64,
    /// FNV-1a digest of the report's deterministic surface (records,
    /// counters, replan outcomes minus wall clocks) — see
    /// [`dynamic_fingerprint`].
    pub fingerprint: u64,
    /// Fingerprint matches the serial row byte-for-byte.
    pub identical: bool,
}

/// Replan decision latencies (milliseconds, min over reps).
#[derive(Clone, Debug)]
pub struct ReplanPerf {
    /// From-scratch `muxserve_placement` on the drifted rates.
    pub full_ms: f64,
    /// `muxserve_placement_warm` on the same rates (local fast path).
    pub warm_ms: f64,
    /// `full_ms / warm_ms`.
    pub speedup: f64,
    /// Warm start on a hopeless spike — includes the internal fallback
    /// to the full search, so it bounds the warm path's worst case.
    pub warm_fallback_ms: f64,
}

/// Migration-cost summary from the dynamic flash-crowd runs (all
/// simulated quantities — deterministic, unlike the wall clocks).
#[derive(Clone, Debug)]
pub struct MigrationPerf {
    /// Blackout run: Σ per-LLM unavailability, LLM-seconds.
    pub blackout_downtime_s: f64,
    /// Blackout run: Σ cost charged to the policy.
    pub blackout_cost: f64,
    /// Staged run: Σ per-LLM unavailability, LLM-seconds.
    pub staged_downtime_s: f64,
    /// Staged run: Σ priced plan cost.
    pub staged_cost: f64,
    /// Staged run: requests resumed from copied KV without recompute.
    pub kv_resumed: usize,
}

/// Everything `bench-perf` measures.
#[derive(Clone, Debug)]
pub struct PerfReport {
    pub n_llms: usize,
    pub gpus: usize,
    pub duration: f64,
    pub smoke: bool,
    /// Cold (deployment-time) placement latency, milliseconds.
    pub placement_cold_ms: f64,
    /// Unit-estimate memo counters from the cold placement search
    /// (ROADMAP "Scale": the per-candidate fixpoint, memoized across
    /// mesh groups); the rate is `PlacementCache::hit_rate` at search
    /// end.
    pub placement_cache_hits: u64,
    pub placement_cache_misses: u64,
    pub placement_cache_hit_rate: f64,
    /// Merged memo counters from one warm-start invocation whose local
    /// passes failed and fell back to the cold search — warm passes and
    /// fallback share a single [`PlacementCache`], so fallback hits
    /// here measure the cross-phase reuse.
    pub warm_cache_hits: u64,
    pub warm_cache_misses: u64,
    pub warm_cache_hit_rate: f64,
    pub sims: Vec<SimPerf>,
    /// Shard-scaling sweep (1/2/4 shards over one dynamic replay).
    pub shard_scaling: Vec<ShardPerf>,
    pub replan: ReplanPerf,
    pub migration: MigrationPerf,
    /// Worker shards the dynamic `sims` rows ran with (`--shards`).
    pub shards: usize,
    /// Whole-benchmark wall clock, seconds (the `--max-wall` subject).
    pub wall_total_s: f64,
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

impl PerfReport {
    /// Serialize in the BENCH_N.json schema. `timing == false` strips
    /// every host-dependent field (wall clocks, events/sec, replan
    /// latencies, the shard knob) so two runs of the same config — at
    /// *any* shard counts — emit byte-identical output; the CI
    /// determinism tripwire diffs exactly that.
    pub fn to_json(&self, timing: bool) -> Json {
        let mut cfg = BTreeMap::new();
        cfg.insert("n_llms".to_string(), Json::Num(self.n_llms as f64));
        cfg.insert("gpus".to_string(), Json::Num(self.gpus as f64));
        cfg.insert("duration_s".to_string(), Json::Num(self.duration));
        cfg.insert("smoke".to_string(), Json::Bool(self.smoke));
        if timing {
            cfg.insert("shards".to_string(), Json::Num(self.shards as f64));
        }

        let sims: Vec<Json> = self
            .sims
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert(
                    "label".to_string(),
                    Json::Str(s.label.to_string()),
                );
                m.insert("requests".to_string(), Json::Num(s.requests as f64));
                m.insert(
                    "completed".to_string(),
                    Json::Num(s.completed as f64),
                );
                m.insert("events".to_string(), Json::Num(s.events as f64));
                if timing {
                    m.insert(
                        "wall_s".to_string(),
                        Json::Num(round3(s.wall_s)),
                    );
                    m.insert(
                        "events_per_s".to_string(),
                        Json::Num(s.events_per_s.round()),
                    );
                }
                Json::Obj(m)
            })
            .collect();

        let shard_scaling: Vec<Json> = self
            .shard_scaling
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("shards".to_string(), Json::Num(s.shards as f64));
                m.insert("events".to_string(), Json::Num(s.events as f64));
                m.insert(
                    "fingerprint".to_string(),
                    Json::Str(format!("{:016x}", s.fingerprint)),
                );
                m.insert("identical".to_string(), Json::Bool(s.identical));
                if timing {
                    m.insert(
                        "wall_s".to_string(),
                        Json::Num(round3(s.wall_s)),
                    );
                    m.insert(
                        "events_per_s".to_string(),
                        Json::Num(s.events_per_s.round()),
                    );
                    m.insert(
                        "speedup".to_string(),
                        Json::Num(round3(s.speedup)),
                    );
                }
                Json::Obj(m)
            })
            .collect();

        let mut rp = BTreeMap::new();
        rp.insert("full_ms".to_string(), Json::Num(round3(self.replan.full_ms)));
        rp.insert("warm_ms".to_string(), Json::Num(round3(self.replan.warm_ms)));
        rp.insert(
            "speedup".to_string(),
            Json::Num(round3(self.replan.speedup)),
        );
        rp.insert(
            "warm_fallback_ms".to_string(),
            Json::Num(round3(self.replan.warm_fallback_ms)),
        );

        let mut mg = BTreeMap::new();
        mg.insert(
            "blackout_downtime_s".to_string(),
            Json::Num(round3(self.migration.blackout_downtime_s)),
        );
        mg.insert(
            "blackout_cost".to_string(),
            Json::Num(round3(self.migration.blackout_cost)),
        );
        mg.insert(
            "staged_downtime_s".to_string(),
            Json::Num(round3(self.migration.staged_downtime_s)),
        );
        mg.insert(
            "staged_cost".to_string(),
            Json::Num(round3(self.migration.staged_cost)),
        );
        mg.insert(
            "kv_resumed".to_string(),
            Json::Num(self.migration.kv_resumed as f64),
        );

        let mut pc = BTreeMap::new();
        pc.insert(
            "hits".to_string(),
            Json::Num(self.placement_cache_hits as f64),
        );
        pc.insert(
            "misses".to_string(),
            Json::Num(self.placement_cache_misses as f64),
        );
        pc.insert(
            "hit_rate".to_string(),
            Json::Num(round3(self.placement_cache_hit_rate)),
        );

        let mut wc = BTreeMap::new();
        wc.insert(
            "hits".to_string(),
            Json::Num(self.warm_cache_hits as f64),
        );
        wc.insert(
            "misses".to_string(),
            Json::Num(self.warm_cache_misses as f64),
        );
        wc.insert(
            "hit_rate".to_string(),
            Json::Num(round3(self.warm_cache_hit_rate)),
        );

        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("bench-perf".to_string()));
        root.insert(
            "generator".to_string(),
            Json::Str(
                "muxserve bench-perf --out BENCH_N.json (regenerate on \
                 the target host; wall-clock numbers are host-dependent)"
                    .to_string(),
            ),
        );
        root.insert("config".to_string(), Json::Obj(cfg));
        if timing {
            root.insert(
                "placement_cold_ms".to_string(),
                Json::Num(round3(self.placement_cold_ms)),
            );
        }
        root.insert("placement_cache".to_string(), Json::Obj(pc));
        root.insert("warm_fallback_cache".to_string(), Json::Obj(wc));
        root.insert("sims".to_string(), Json::Arr(sims));
        root.insert("shard_scaling".to_string(), Json::Arr(shard_scaling));
        if timing {
            root.insert("replan".to_string(), Json::Obj(rp));
        }
        root.insert("migration".to_string(), Json::Obj(mg));
        if timing {
            root.insert(
                "wall_total_s".to_string(),
                Json::Num(round3(self.wall_total_s)),
            );
        }
        Json::Obj(root)
    }
}

/// FNV-1a accumulator for the report digest.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn u(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    fn f(&mut self, x: f64) {
        self.u(x.to_bits());
    }
}

/// Digest of a [`DynamicReport`]'s deterministic surface: every request
/// record, per-LLM counter, replan outcome (minus `decision_ms` — the
/// one host-dependent field), cache and fault counters. Bit-exact: two
/// runs agree on this digest iff they agree on every hashed field down
/// to float bit patterns, which is the sharded engine's byte-identity
/// contract (`--shards N` must reproduce serial exactly).
pub fn dynamic_fingerprint(r: &DynamicReport) -> u64 {
    let mut h = Fnv::new();
    for rec in &r.eval.records {
        h.u(rec.id);
        h.u(rec.llm as u64);
        h.f(rec.arrival);
        h.f(rec.first_token);
        h.f(rec.finish);
        h.u(rec.prompt_len as u64);
        h.u(rec.output_len as u64);
        h.f(rec.ideal_latency);
        h.u(u64::from(rec.tier.code()));
    }
    for o in &r.replans {
        h.f(o.time);
        h.u(u64::from(o.migrated));
        h.f(o.drift);
        for rate in &o.rates {
            h.f(*rate);
        }
        h.u(o.units as u64);
        h.u(u64::from(o.warm));
        h.f(o.cost);
        h.f(o.window_s);
    }
    h.u(r.migrations as u64);
    h.u(r.dropped as u64);
    h.u(r.events);
    h.f(r.downtime_s);
    h.f(r.migration_cost);
    h.u(r.kv_resumed as u64);
    h.u(r.cache.prefix_hits);
    h.u(r.cache.prefix_misses);
    h.f(r.cache.prefill_s);
    h.f(r.cache.prefill_skip_s);
    h.u(r.cache.swaps_out);
    h.u(r.cache.swaps_in);
    h.u(r.cache.recompute_preempts);
    h.u(r.cache.host_peak_blocks as u64);
    h.f(r.cache.swap_link_s);
    for s in r.shed {
        h.u(s);
    }
    h.u(r.fault.injected as u64);
    h.u(r.fault.unit_failures as u64);
    h.u(r.fault.repairs as u64);
    h.u(r.fault.lost_requests as u64);
    h.u(r.fault.recovered_requests as u64);
    h.u(r.fault.kv_recovered as u64);
    h.u(r.fault.tokens_recomputed);
    h.u(r.fault.copy_retries as u64);
    h.u(r.fault.copy_fallbacks as u64);
    h.f(r.fault.mttr_s.unwrap_or(-1.0));
    for a in &r.fault.availability {
        h.f(*a);
    }
    h.f(r.fault.slo_reattain_s.unwrap_or(-1.0));
    for v in [
        &r.admitted,
        &r.lost,
        &r.in_flight,
        &r.shed_llm,
        &r.dropped_llm,
    ] {
        for x in v {
            h.u(*x);
        }
    }
    h.0
}

/// Minimum wall time of `reps` calls, in milliseconds.
fn time_ms<T>(reps: u32, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

/// The benchmark scale: (analytic zoo, cluster, power-law alpha, max rate).
fn perf_scale(smoke: bool) -> (Vec<ModelSpec>, ClusterSpec, f64, f64) {
    if smoke {
        let sc = Scenario {
            n_llms: 6,
            ..Scenario::new(ScenarioShape::Stationary)
        };
        (sc.model_specs(), ClusterSpec::new(4, 1), 1.7, 6.0)
    } else {
        (synthetic_zoo(), ClusterSpec::paper_testbed(), 0.9, 20.0)
    }
}

/// Run the whole benchmark; deterministic modulo wall-clock noise.
pub fn run_bench_perf(cfg: &PerfConfig) -> PerfReport {
    let (specs, cluster, alpha, max_rate) = perf_scale(cfg.smoke);
    let n = specs.len();
    let t_all = Instant::now();

    // 1. Cold placement + stationary event loop.
    let (workloads, requests) =
        synthetic_workload(n, alpha, max_rate, cfg.duration, 2024);
    let engine = EngineConfig::muxserve();
    let cost = CostModel::new(cluster.gpu.clone());
    let est = Estimator::with_kv_frac(cost.clone(), engine.kv_capacity_frac);
    let mut cache = PlacementCache::default();
    let t0 = Instant::now();
    let placement = muxserve_placement_cached(
        &specs, &workloads, &cluster, &est, &mut cache,
    )
    .expect("bench-perf scale must have a feasible placement");
    let placement_cold_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut sims = Vec::new();
    {
        let mut sim = Simulation::from_placement(
            &placement, &specs, &workloads, engine, &cost,
        );
        let t0 = Instant::now();
        let eval = sim.run(&requests, cfg.duration);
        let wall = t0.elapsed().as_secs_f64();
        sims.push(SimPerf {
            label: "stationary",
            requests: requests.len(),
            completed: eval.records.len(),
            events: sim.events_processed(),
            wall_s: wall,
            events_per_s: sim.events_processed() as f64 / wall.max(1e-9),
        });
    }

    // 2. Flash-crowd with the online re-placement loop armed (warm
    // optimizer), once per migration executor — the staged run also
    // supplies the BENCH migration-cost summary.
    let migration = {
        let scenario = Scenario {
            n_llms: n,
            duration: cfg.duration,
            alpha,
            max_rate,
            seed: 2024,
            ..Scenario::new(ScenarioShape::FlashCrowd)
        };
        let data = scenario.build();
        // Same analytic zoo as the stationary section (NOT the scenario's
        // small-model zoo), so every BENCH row shares one model mix.
        let mut run_mode = |label: &'static str, mode: MigrationMode| {
            let rcfg = ReplanConfig {
                warm_start: true,
                migration_mode: mode,
                shards: cfg.shards,
                ..Default::default()
            };
            let dyn_sim = DynamicSimulation::new(
                &specs,
                &data.planning_workloads,
                &cluster,
                engine,
                rcfg,
                true,
            )
            .expect("bench-perf flash-crowd placement must exist");
            let t0 = Instant::now();
            let report = dyn_sim.run(&data.requests, cfg.duration);
            let wall = t0.elapsed().as_secs_f64();
            sims.push(SimPerf {
                label,
                requests: data.requests.len(),
                completed: report.eval.records.len(),
                events: report.events,
                wall_s: wall,
                events_per_s: report.events as f64 / wall.max(1e-9),
            });
            report
        };
        let blackout =
            run_mode("flash-crowd+replan", MigrationMode::Blackout);
        let staged = run_mode("flash-crowd+staged", MigrationMode::Staged);
        MigrationPerf {
            blackout_downtime_s: blackout.downtime_s,
            blackout_cost: blackout.migration_cost,
            staged_downtime_s: staged.downtime_s,
            staged_cost: staged.migration_cost,
            kv_resumed: staged.kv_resumed,
        }
    };

    // 3. Shard scaling: one stationary replay through the *dynamic*
    // engine (adapt ticks and replan barriers armed) at 1/2/4 worker
    // shards. Every simulated quantity must agree bit-for-bit with the
    // serial row — `identical` is the in-report determinism verdict —
    // while events/sec is the speedup headline.
    let shard_scaling: Vec<ShardPerf> = {
        let mut rows: Vec<ShardPerf> = Vec::new();
        for k in [1usize, 2, 4] {
            let rcfg = ReplanConfig {
                warm_start: true,
                shards: k,
                ..Default::default()
            };
            let dyn_sim = DynamicSimulation::new(
                &specs, &workloads, &cluster, engine, rcfg, true,
            )
            .expect("bench-perf shard-scaling placement must exist");
            let t0 = Instant::now();
            let report = dyn_sim.run(&requests, cfg.duration);
            let wall = t0.elapsed().as_secs_f64();
            let events_per_s = report.events as f64 / wall.max(1e-9);
            let fingerprint = dynamic_fingerprint(&report);
            let (speedup, identical) = match rows.first() {
                None => (1.0, true),
                Some(serial) => (
                    events_per_s / serial.events_per_s.max(1e-9),
                    fingerprint == serial.fingerprint
                        && report.events == serial.events,
                ),
            };
            rows.push(ShardPerf {
                shards: k,
                events: report.events,
                wall_s: wall,
                events_per_s,
                speedup,
                fingerprint,
                identical,
            });
        }
        rows
    };

    // 4. Replan decision latency on one drifted rate vector: a sag on the
    // hottest LLM is always locally absorbable, so it exercises the warm
    // fast path; the ×50 spike forces the documented fallback.
    let mut drifted = workloads.clone();
    drifted[0].rate = (drifted[0].rate * 0.25).max(0.05);
    let dirty: Vec<bool> = (0..n).map(|i| i == 0).collect();
    let full_ms = time_ms(cfg.reps, || {
        muxserve_placement(&specs, &drifted, &cluster, &est)
    });
    let warm_ms = time_ms(cfg.reps, || {
        muxserve_placement_warm(
            &specs, &drifted, &cluster, &est, &placement, &dirty,
        )
    });
    let mut spiked = workloads.clone();
    spiked[0].rate *= 50.0;
    let warm_fallback_ms = time_ms(cfg.reps, || {
        muxserve_placement_warm(
            &specs, &spiked, &cluster, &est, &placement, &dirty,
        )
    });

    // The spike forces the warm passes through to the cold fallback;
    // one instrumented (untimed) invocation reports the merged memo
    // counters — fallback hits measure how much of the warm passes'
    // pricing the re-search reused.
    let mut warm_cache = PlacementCache::default();
    let _ = muxserve_placement_warm_cached(
        &specs, &spiked, &cluster, &est, &placement, &dirty,
        &mut warm_cache,
    );

    PerfReport {
        n_llms: n,
        gpus: cluster.total_gpus(),
        duration: cfg.duration,
        smoke: cfg.smoke,
        placement_cold_ms,
        placement_cache_hits: cache.hits,
        placement_cache_misses: cache.misses,
        placement_cache_hit_rate: cache.hit_rate(),
        warm_cache_hits: warm_cache.hits,
        warm_cache_misses: warm_cache.misses,
        warm_cache_hit_rate: warm_cache.hit_rate(),
        sims,
        shard_scaling,
        replan: ReplanPerf {
            full_ms,
            warm_ms,
            speedup: full_ms / warm_ms.max(1e-9),
            warm_fallback_ms,
        },
        migration,
        shards: cfg.shards,
        wall_total_s: t_all.elapsed().as_secs_f64(),
    }
}
