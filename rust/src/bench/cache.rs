//! `bench-cache`: the KV cache-layer figure — eviction policy × host
//! tier on shared-prefix workloads, on identical request streams.
//!
//! Each scenario shape is materialized ONCE (with the configured
//! shared-prefix fraction) and replayed through every `eviction ×
//! host-tier` combination under a static placement, so per-cell
//! differences in hit rate, skipped prefill seconds, swap traffic, and
//! SLO attainment are attributable to the cache layer alone. The
//! `eviction=none` row is the pre-cache engine and serves as the
//! baseline; host-tier capacity is irrelevant there, so that row runs
//! once regardless of the host grid.
//!
//! All columns are deterministic in the config: two runs produce
//! byte-identical [`CacheReport::to_json`] / [`CacheReport::to_markdown`]
//! output (pinned by a test), the same contract the `ab` harness keeps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::bench::drift::{run_scenario_cfg, scenario_cluster};
use crate::coordinator::EngineConfig;
use crate::memory::EvictionKind;
use crate::util::json::Json;
use crate::workload::{Scenario, ScenarioShape};

/// Knobs of one `bench-cache` run.
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Simulated seconds per run.
    pub duration: f64,
    /// Workload seed (shared by every cell — identical streams).
    pub seed: u64,
    /// Scenario shapes to run.
    pub shapes: Vec<ScenarioShape>,
    /// Fraction of requests carrying a shared prompt prefix.
    pub shared_prefix: f64,
    /// Eviction policies to compare (`none` = the pre-cache engine).
    pub evictions: Vec<EvictionKind>,
    /// Host-DRAM tier capacities (blocks per unit) crossed with the
    /// policies; 0 = evictions fall back to preempt-and-recompute.
    pub host_tier_blocks: Vec<usize>,
    /// KV capacity fraction for every run — below 1.0 shrinks the device
    /// pool so eviction pressure actually materializes.
    pub kv_frac: f64,
    /// SLO scale for attainment reporting.
    pub slo_scale: f64,
}

impl CacheConfig {
    /// The full figure: a stationary control and a flash-crowd stressor,
    /// every eviction policy, with and without a host tier, on a
    /// deliberately tightened device pool.
    pub fn full() -> CacheConfig {
        CacheConfig {
            duration: 120.0,
            seed: 2024,
            shapes: vec![ScenarioShape::Stationary, ScenarioShape::FlashCrowd],
            shared_prefix: 0.5,
            evictions: EvictionKind::all().to_vec(),
            host_tier_blocks: vec![0, 1 << 20],
            kv_frac: 0.6,
            slo_scale: 8.0,
        }
    }

    /// CI smoke: one stressor shape, shorter runs, same grid otherwise.
    pub fn smoke() -> CacheConfig {
        CacheConfig {
            duration: 60.0,
            shapes: vec![ScenarioShape::FlashCrowd],
            ..CacheConfig::full()
        }
    }
}

/// One `eviction × host-tier` run's row in the comparison.
#[derive(Clone, Debug)]
pub struct CacheCell {
    pub shape: &'static str,
    /// Eviction policy name ("none" = cache layer off).
    pub eviction: &'static str,
    /// Host-tier capacity this cell ran with (blocks per unit).
    pub host_blocks: usize,
    pub arrived: usize,
    pub completed: usize,
    pub dropped: usize,
    /// SLO attainment at the configured scale (rounded to 1e-4).
    pub slo: f64,
    /// p99 request latency, seconds (rounded to 1e-3).
    pub p99_latency: f64,
    /// Prefix-cache hit rate over prefix-carrying admissions (1e-4).
    pub hit_rate: f64,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// Prefill seconds actually spent (rounded to 1e-4).
    pub prefill_s: f64,
    /// Prefill seconds avoided by prefix sharing (rounded to 1e-4).
    pub prefill_skip_s: f64,
    pub swaps_out: u64,
    pub swaps_in: u64,
    /// Evictions that fell back to preempt-and-recompute.
    pub recompute_preempts: u64,
    /// High-water mark of host-tier blocks in use.
    pub host_peak_blocks: usize,
}

/// Everything one `bench-cache` invocation measured.
#[derive(Clone, Debug)]
pub struct CacheReport {
    pub duration: f64,
    pub seed: u64,
    pub shared_prefix: f64,
    pub kv_frac: f64,
    pub slo_scale: f64,
    pub cells: Vec<CacheCell>,
}

fn round(x: f64, unit: f64) -> f64 {
    (x / unit).round() * unit
}

impl CacheReport {
    /// The comparison as a markdown table, one row per cell. Every
    /// column is deterministic in the config.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "## bench-cache: eviction × host tier ({}s, seed {}, \
             shared-prefix {}, kv-frac {}, slo@{})",
            self.duration,
            self.seed,
            self.shared_prefix,
            self.kv_frac,
            self.slo_scale
        );
        let _ = writeln!(
            out,
            "| scenario | eviction | host-blocks | hit-rate | hits/miss \
             | prefill(s) | skipped(s) | swap-out | swap-in | recompute \
             | host-peak | slo | p99(s) | done/arrived |"
        );
        let _ = writeln!(
            out,
            "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"
        );
        for c in &self.cells {
            let _ = writeln!(
                out,
                "| {} | {} | {} | {:.4} | {}/{} | {:.4} | {:.4} | {} | \
                 {} | {} | {} | {:.4} | {:.3} | {}/{} |",
                c.shape,
                c.eviction,
                c.host_blocks,
                c.hit_rate,
                c.prefix_hits,
                c.prefix_misses,
                c.prefill_s,
                c.prefill_skip_s,
                c.swaps_out,
                c.swaps_in,
                c.recompute_preempts,
                c.host_peak_blocks,
                c.slo,
                c.p99_latency,
                c.completed,
                c.arrived
            );
        }
        out
    }

    /// The comparison in the CACHE_N.json schema (byte-reproducible in
    /// the config — the determinism test compares this).
    pub fn to_json(&self) -> Json {
        let mut cfg = BTreeMap::new();
        cfg.insert("duration_s".to_string(), Json::Num(self.duration));
        cfg.insert("seed".to_string(), Json::Num(self.seed as f64));
        cfg.insert(
            "shared_prefix".to_string(),
            Json::Num(self.shared_prefix),
        );
        cfg.insert("kv_frac".to_string(), Json::Num(self.kv_frac));
        cfg.insert("slo_scale".to_string(), Json::Num(self.slo_scale));

        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert(
                    "shape".to_string(),
                    Json::Str(c.shape.to_string()),
                );
                m.insert(
                    "eviction".to_string(),
                    Json::Str(c.eviction.to_string()),
                );
                m.insert(
                    "host_blocks".to_string(),
                    Json::Num(c.host_blocks as f64),
                );
                m.insert(
                    "arrived".to_string(),
                    Json::Num(c.arrived as f64),
                );
                m.insert(
                    "completed".to_string(),
                    Json::Num(c.completed as f64),
                );
                m.insert(
                    "dropped".to_string(),
                    Json::Num(c.dropped as f64),
                );
                m.insert("slo".to_string(), Json::Num(c.slo));
                m.insert(
                    "p99_latency_s".to_string(),
                    Json::Num(c.p99_latency),
                );
                m.insert("hit_rate".to_string(), Json::Num(c.hit_rate));
                m.insert(
                    "prefix_hits".to_string(),
                    Json::Num(c.prefix_hits as f64),
                );
                m.insert(
                    "prefix_misses".to_string(),
                    Json::Num(c.prefix_misses as f64),
                );
                m.insert(
                    "prefill_s".to_string(),
                    Json::Num(c.prefill_s),
                );
                m.insert(
                    "prefill_skip_s".to_string(),
                    Json::Num(c.prefill_skip_s),
                );
                m.insert(
                    "swaps_out".to_string(),
                    Json::Num(c.swaps_out as f64),
                );
                m.insert(
                    "swaps_in".to_string(),
                    Json::Num(c.swaps_in as f64),
                );
                m.insert(
                    "recompute_preempts".to_string(),
                    Json::Num(c.recompute_preempts as f64),
                );
                m.insert(
                    "host_peak_blocks".to_string(),
                    Json::Num(c.host_peak_blocks as f64),
                );
                Json::Obj(m)
            })
            .collect();

        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("cache".to_string()));
        root.insert(
            "generator".to_string(),
            Json::Str(
                "muxserve bench-cache --out CACHE_N.json (every field \
                 is deterministic in the config)"
                    .to_string(),
            ),
        );
        root.insert("config".to_string(), Json::Obj(cfg));
        root.insert("cells".to_string(), Json::Arr(cells));
        Json::Obj(root)
    }
}

/// Run the whole grid. Scenarios that admit no initial placement are
/// skipped (none of the built-in shapes do on the default cluster).
pub fn run_bench_cache(cfg: &CacheConfig) -> CacheReport {
    let cluster = scenario_cluster();
    let mut cells = Vec::new();
    for &shape in &cfg.shapes {
        let scenario = Scenario {
            duration: cfg.duration,
            seed: cfg.seed,
            shared_prefix: cfg.shared_prefix,
            ..Scenario::new(shape)
        };
        // One materialization per shape: every cell below replays the
        // exact same request stream.
        let data = scenario.build();
        let arrived = data.requests.len();
        for &eviction in &cfg.evictions {
            // With the cache layer off the host tier is inert — one
            // baseline row instead of a duplicate per host capacity.
            let hosts: Vec<usize> =
                if matches!(eviction, EvictionKind::None) {
                    vec![0]
                } else {
                    cfg.host_tier_blocks.clone()
                };
            for host in hosts {
                let engine = EngineConfig {
                    eviction,
                    host_tier_blocks: host,
                    kv_capacity_frac: cfg.kv_frac,
                    ..EngineConfig::muxserve()
                };
                let Some(report) = run_scenario_cfg(
                    &scenario,
                    &data,
                    &cluster,
                    engine,
                    None,
                ) else {
                    continue;
                };
                let s = &report.cache;
                cells.push(CacheCell {
                    shape: shape.name(),
                    eviction: eviction.name(),
                    host_blocks: host,
                    arrived,
                    completed: report.eval.records.len(),
                    dropped: report.dropped,
                    slo: round(
                        report.eval.slo_attainment(cfg.slo_scale),
                        1e-4,
                    ),
                    p99_latency: round(
                        report.eval.latency_summary().p99(),
                        1e-3,
                    ),
                    hit_rate: round(s.hit_rate(), 1e-4),
                    prefix_hits: s.prefix_hits,
                    prefix_misses: s.prefix_misses,
                    prefill_s: round(s.prefill_s, 1e-4),
                    prefill_skip_s: round(s.prefill_skip_s, 1e-4),
                    swaps_out: s.swaps_out,
                    swaps_in: s.swaps_in,
                    recompute_preempts: s.recompute_preempts,
                    host_peak_blocks: s.host_peak_blocks,
                });
            }
        }
    }
    CacheReport {
        duration: cfg.duration,
        seed: cfg.seed,
        shared_prefix: cfg.shared_prefix,
        kv_frac: cfg.kv_frac,
        slo_scale: cfg.slo_scale,
        cells,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_report_is_deterministic_and_measures_sharing() {
        // A reduced grid keeps the test fast: the pre-cache baseline
        // plus one real policy, one host capacity, one stressor shape.
        let cfg = CacheConfig {
            duration: 40.0,
            shapes: vec![ScenarioShape::FlashCrowd],
            shared_prefix: 0.6,
            evictions: vec![EvictionKind::None, EvictionKind::Lru],
            host_tier_blocks: vec![1 << 20],
            ..CacheConfig::full()
        };
        let a = run_bench_cache(&cfg);
        let b = run_bench_cache(&cfg);
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "same seed must give a byte-identical comparison"
        );
        assert_eq!(a.to_markdown(), b.to_markdown());
        // One baseline row (none ignores the host grid) + one lru row.
        assert_eq!(a.cells.len(), 2, "cells: {:?}", a.cells);

        let none = &a.cells[0];
        assert_eq!(none.eviction, "none");
        assert!(none.hit_rate == 0.0, "cache off tracks no hits");
        assert!(none.prefill_skip_s == 0.0, "cache off skips nothing");
        assert!(none.prefill_s > 0.0);

        let lru = &a.cells[1];
        assert_eq!(lru.eviction, "lru");
        assert!(lru.prefix_hits > 0, "shared prefixes must hit: {lru:?}");
        assert!(lru.hit_rate > 0.0);
        assert!(
            lru.prefill_skip_s > 0.0,
            "hits must skip prefill work: {lru:?}"
        );
        // Same stream, and hits shave the shared prefix off each
        // prefill: the per-prefill average must drop vs. the baseline.
        let avg_none = none.prefill_s / none.completed.max(1) as f64;
        let avg_lru = lru.prefill_s / lru.completed.max(1) as f64;
        assert!(
            avg_lru < avg_none,
            "sharing must cut mean prefill: {avg_lru} vs {avg_none}"
        );
    }
}
