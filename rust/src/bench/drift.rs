//! Drift figure: online re-placement vs. static placement on dynamic
//! workloads — the evaluation axis the paper's stationary setup (§4.2)
//! cannot express. One row per (scenario shape, adaptation mode).

use crate::config::{ClusterSpec, WorkloadSpec};
use crate::coordinator::{EngineConfig, ReplanConfig};
use crate::metrics::Evaluation;
use crate::simulator::{
    DynamicReport, DynamicSimulation, FaultPlan, FaultsAxis,
};
use crate::workload::{Request, Scenario, ScenarioData, ScenarioShape};

/// Outcome of one scenario run (static or adaptive).
pub struct ScenarioResult {
    pub shape: &'static str,
    pub adaptive: bool,
    pub completed: usize,
    pub arrived: usize,
    pub throughput: f64,
    pub slo8: f64,
    pub p99_latency: f64,
    pub migrations: usize,
    pub dropped: usize,
}

impl ScenarioResult {
    fn from_report(
        shape: &'static str,
        adaptive: bool,
        arrived: usize,
        report: &DynamicReport,
    ) -> ScenarioResult {
        let eval: &Evaluation = &report.eval;
        ScenarioResult {
            shape,
            adaptive,
            completed: eval.records.len(),
            arrived,
            throughput: eval.total_throughput(),
            slo8: eval.slo_attainment(8.0),
            p99_latency: eval.latency_summary().p99(),
            migrations: report.migrations,
            dropped: report.dropped,
        }
    }
}

/// Default cluster for the dynamic scenarios: four single-GPU meshes, so
/// colocation is forced (6 LLMs on 4 units) and placement decisions bind.
pub fn scenario_cluster() -> ClusterSpec {
    ClusterSpec::new(4, 1)
}

/// Run an already-materialized scenario with adaptation on or off
/// (None when no placement exists). Lets callers reuse one
/// [`ScenarioData`] across the static run, the adaptive run, and a
/// trace export without re-synthesizing the stream.
pub fn run_scenario_on(
    scenario: &Scenario,
    data: &ScenarioData,
    cluster: &ClusterSpec,
    replan: Option<ReplanConfig>,
) -> Option<DynamicReport> {
    run_scenario_cfg(scenario, data, cluster, EngineConfig::muxserve(), replan)
}

/// Like [`run_scenario_on`], but with an explicit [`EngineConfig`] — the
/// entry point for runs that ablate engine switches (eviction policy,
/// host-tier capacity) rather than placement adaptation.
pub fn run_scenario_cfg(
    scenario: &Scenario,
    data: &ScenarioData,
    cluster: &ClusterSpec,
    cfg: EngineConfig,
    replan: Option<ReplanConfig>,
) -> Option<DynamicReport> {
    run_scenario_faults(
        scenario,
        data,
        cluster,
        cfg,
        replan,
        FaultsAxis::None,
    )
}

/// Like [`run_scenario_cfg`], with a chaos schedule injected: the
/// `faults` axis is materialized with the scenario's own seed, so one
/// (scenario, axis) pair names a fully reproducible fault run.
pub fn run_scenario_faults(
    scenario: &Scenario,
    data: &ScenarioData,
    cluster: &ClusterSpec,
    cfg: EngineConfig,
    replan: Option<ReplanConfig>,
    faults: FaultsAxis,
) -> Option<DynamicReport> {
    let specs = scenario.model_specs();
    let adaptive = replan.is_some();
    let mut sim = DynamicSimulation::new(
        &specs,
        &data.planning_workloads,
        cluster,
        cfg,
        replan.unwrap_or_default(),
        adaptive,
    )?;
    if let Some(plan) = faults.plan(scenario.seed, scenario.duration) {
        sim = sim.with_faults(&plan);
    }
    Some(sim.run(&data.requests, scenario.duration))
}

/// Run one scenario once, with adaptation on or off. Returns the full
/// dynamic report plus the arrival count (None when no placement exists).
pub fn run_scenario(
    scenario: &Scenario,
    cluster: &ClusterSpec,
    replan: Option<ReplanConfig>,
) -> Option<(DynamicReport, usize)> {
    let data = scenario.build();
    let report = run_scenario_on(scenario, &data, cluster, replan)?;
    Some((report, data.requests.len()))
}

/// Replay a frozen trace (see [`crate::workload::read_trace_file`])
/// through the dynamic engine. The planning workloads are estimated from
/// the trace's initial 30% window — the same history-based view a static
/// optimizer plans from — so exported scenarios replay faithfully and
/// external traces slot straight in. Returns `None` when no placement
/// exists for the estimated rates.
pub fn run_trace(
    requests: &[Request],
    duration: f64,
    cluster: &ClusterSpec,
    engine: EngineConfig,
    replan: Option<ReplanConfig>,
) -> Option<DynamicReport> {
    run_trace_faults(
        requests,
        duration,
        cluster,
        engine,
        replan,
        &FaultPlan::default(),
    )
}

/// Like [`run_trace`], replaying an explicit fault schedule alongside
/// the requests — the v4-trace path, where the chaos schedule was
/// frozen into the file next to the workload it hit.
pub fn run_trace_faults(
    requests: &[Request],
    duration: f64,
    cluster: &ClusterSpec,
    engine: EngineConfig,
    replan: Option<ReplanConfig>,
    faults: &FaultPlan,
) -> Option<DynamicReport> {
    let n_llms = requests.iter().map(|r| r.llm + 1).max()?;
    let window = (0.30 * duration).max(1e-9);
    let mut counts = vec![0usize; n_llms];
    for r in requests.iter().filter(|r| r.arrival < window) {
        counts[r.llm] += 1;
    }
    let workloads: Vec<WorkloadSpec> = counts
        .iter()
        .map(|c| WorkloadSpec::sharegpt((*c as f64 / window).max(0.05)))
        .collect();
    let specs = Scenario {
        n_llms,
        ..Scenario::new(ScenarioShape::Stationary)
    }
    .model_specs();
    let adaptive = replan.is_some();
    let sim = DynamicSimulation::new(
        &specs,
        &workloads,
        cluster,
        engine,
        replan.unwrap_or_default(),
        adaptive,
    )?
    .with_faults(faults);
    Some(sim.run(requests, duration))
}

/// The drift-vs-static figure: every scenario shape, static then
/// adaptive, on a shared workload per shape.
pub fn fig_drift(duration: f64, seed: u64) -> Vec<ScenarioResult> {
    let cluster = scenario_cluster();
    let mut out = Vec::new();
    println!(
        "\n== Drift figure: static vs online re-placement \
         (6 LLMs, 4x1 GPUs, {duration:.0}s) =="
    );
    println!(
        "{:<12} {:<9} {:>5} {:>6} {:>7} {:>6} {:>8} {:>5}",
        "shape", "mode", "done", "arriv", "tpt", "slo@8", "p99(s)", "migr"
    );
    for shape in ScenarioShape::all() {
        let scenario = Scenario {
            duration,
            seed,
            ..Scenario::new(shape)
        };
        for adaptive in [false, true] {
            let replan = adaptive.then(ReplanConfig::default);
            let Some((report, arrived)) =
                run_scenario(&scenario, &cluster, replan)
            else {
                println!("{:<12} infeasible placement", shape.name());
                continue;
            };
            let row = ScenarioResult::from_report(
                shape.name(),
                adaptive,
                arrived,
                &report,
            );
            println!(
                "{:<12} {:<9} {:>5} {:>6} {:>7.2} {:>6.2} {:>8.2} {:>5}",
                row.shape,
                if adaptive { "replan" } else { "static" },
                row.completed,
                row.arrived,
                row.throughput,
                row.slo8,
                row.p99_latency,
                row.migrations
            );
            out.push(row);
        }
    }
    out
}
