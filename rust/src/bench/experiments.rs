//! Experiment drivers — one per paper figure (see DESIGN.md §4).
//!
//! Each driver prints the same rows/series the paper reports and returns
//! structured results so tests can assert the qualitative shapes.

use crate::config::{synthetic_zoo, ClusterSpec, ModelSpec, WorkloadSpec};
use crate::coordinator::{
    muxserve_placement, spatial_placement, EngineConfig, Placement,
};
use crate::coordinator::estimator::Estimator;
use crate::costmodel::CostModel;
use crate::metrics::Evaluation;
use crate::simulator::Simulation;
use crate::workload::{power_law_rates, synthetic_workload, Request};

/// A (system name, evaluation) pair for comparison tables.
pub struct SystemResult {
    pub name: &'static str,
    pub eval: Evaluation,
    pub rates: Vec<f64>,
}

impl SystemResult {
    pub fn throughput(&self) -> f64 {
        self.eval.aggregate_throughput(&self.rates)
    }
}

/// Run one (placement, engine config) against a request stream.
pub fn run_system(
    placement: &Placement,
    specs: &[ModelSpec],
    workloads: &[WorkloadSpec],
    cfg: EngineConfig,
    requests: &[Request],
    duration: f64,
) -> Evaluation {
    let cost = CostModel::a100();
    let mut sim =
        Simulation::from_placement(placement, specs, workloads, cfg, &cost);
    sim.run(requests, duration)
}

/// Convenience: the three §4.2 systems on a common workload.
pub fn compare_three_systems(
    specs: &[ModelSpec],
    workloads: &[WorkloadSpec],
    cluster: &ClusterSpec,
    requests: &[Request],
    duration: f64,
) -> Vec<SystemResult> {
    let est = Estimator::new(CostModel::a100());
    let rates: Vec<f64> = workloads.iter().map(|w| w.rate).collect();
    let mut out = Vec::new();

    if let Some(p) = muxserve_placement(specs, workloads, cluster, &est) {
        out.push(SystemResult {
            name: "muxserve",
            eval: run_system(&p, specs, workloads, EngineConfig::muxserve(),
                             requests, duration),
            rates: rates.clone(),
        });
        // Temporal multiplexing shares MuxServe's placement (§4.1) but
        // schedules FCFS one-job-at-a-time.
        out.push(SystemResult {
            name: "temporal",
            eval: run_system(&p, specs, workloads, EngineConfig::temporal(),
                             requests, duration),
            rates: rates.clone(),
        });
    }
    if let Some(p) = spatial_placement(specs, workloads, cluster, &est) {
        out.push(SystemResult {
            name: "spatial",
            eval: run_system(&p, specs, workloads, EngineConfig::spatial(),
                             requests, duration),
            rates,
        });
    }
    out
}

/// Shared §4.2 workload setup: the Table-1 zoo with power-law rates.
pub fn fig5_setup(
    alpha: f64,
    max_rate: f64,
    duration: f64,
    seed: u64,
) -> (Vec<ModelSpec>, Vec<WorkloadSpec>, Vec<Request>) {
    let specs = synthetic_zoo();
    let (workloads, requests) =
        synthetic_workload(specs.len(), alpha, max_rate, duration, seed);
    (specs, workloads, requests)
}

/// Fig. 6 data: cumulative rate share per alpha.
pub fn fig6_series(alphas: &[f64], n_llms: usize) -> Vec<(f64, Vec<f64>)> {
    alphas
        .iter()
        .map(|a| {
            let rates = power_law_rates(n_llms, *a, 20.0);
            (*a, crate::workload::cumulative_rate_distribution(&rates))
        })
        .collect()
}
