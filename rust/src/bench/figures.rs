//! One driver per paper figure (DESIGN.md §4). Each prints the series the
//! paper reports and returns structured data for assertions.

use crate::bench::experiments::{compare_three_systems, fig5_setup, run_system};
use crate::config::{llama_spec, ClusterSpec, ModelSpec, WorkloadSpec};
use crate::coordinator::estimator::{Estimator, UnitMember};
use crate::coordinator::{
    memory_greedy_placement, muxserve_placement, EngineConfig, Placement,
    PlacementUnit, ParallelCandidate,
};
use crate::costmodel::CostModel;
use crate::simulator::Simulation;
use crate::workload::{chatlmsys_like_trace, synthetic_workload, TraceSpec};

fn line(s: &str) {
    println!("{s}");
}

// ---------------------------------------------------------------------------
// Figure 1: GPU utilization of the three multiplexing strategies
// ---------------------------------------------------------------------------

pub struct Fig1Row {
    pub system: &'static str,
    pub utilization: f64,
    pub throughput: f64,
    pub p50_latency: f64,
}

/// Two 7B LLMs on two GPUs; LLM A popular, LLM B sparse (Fig. 1's setup).
pub fn fig1() -> Vec<Fig1Row> {
    let specs = vec![llama_spec("llm-a", 6.7), llama_spec("llm-b", 6.7)];
    let workloads =
        vec![WorkloadSpec::sharegpt(6.0), WorkloadSpec::sharegpt(0.6)];
    let duration = 120.0;
    let (_, requests) = {
        let rates = [6.0, 0.6];
        let specs_w: Vec<WorkloadSpec> =
            rates.iter().map(|r| WorkloadSpec::sharegpt(*r)).collect();
        let mut rng = crate::util::Rng::new(11);
        let streams = specs_w
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut sub = rng.fork(i as u64);
                crate::workload::poisson_requests(i, s, duration, &mut sub)
            })
            .collect();
        (specs_w, crate::workload::merge_streams(streams))
    };
    let cluster = ClusterSpec::new(1, 2);
    let est = Estimator::new(CostModel::a100());
    let cost = CostModel::a100();
    let mut out = Vec::new();

    // Spatial: one GPU per LLM.
    let spatial = crate::coordinator::spatial_placement(
        &specs, &workloads, &cluster, &est,
    )
    .expect("spatial feasible");
    // Temporal + MuxServe: both LLMs colocated on the 2-GPU mesh.
    let colocated =
        muxserve_placement(&specs, &workloads, &cluster, &est).unwrap();

    for (name, placement, cfg) in [
        ("spatial", &spatial, EngineConfig::spatial()),
        ("temporal", &colocated, EngineConfig::temporal()),
        ("muxserve", &colocated, EngineConfig::muxserve()),
    ] {
        let mut sim = Simulation::from_placement(
            placement, &specs, &workloads, cfg, &cost,
        );
        let eval = sim.run(&requests, duration);
        out.push(Fig1Row {
            system: name,
            utilization: sim.avg_gpu_utilization(),
            throughput: eval.total_throughput(),
            p50_latency: eval.latency_summary().p50(),
        });
    }
    line("\n== Figure 1: GPU utilization, 2 LLMs on 2 GPUs ==");
    line("system     util    tpt(req/s)  p50-latency(s)");
    for r in &out {
        line(&format!(
            "{:<10} {:>5.2}   {:>8.2}   {:>10.2}",
            r.system, r.utilization, r.throughput, r.p50_latency
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 2: trace rates over time
// ---------------------------------------------------------------------------

pub fn fig2() -> Vec<Vec<f64>> {
    let spec = TraceSpec { duration: 480.0, ..Default::default() };
    let (_, reqs) = chatlmsys_like_trace(&spec);
    let buckets = 24usize;
    let w = spec.duration / buckets as f64;
    let mut rates = vec![vec![0.0; buckets]; spec.n_llms];
    for r in &reqs {
        rates[r.llm][((r.arrival / w) as usize).min(buckets - 1)] += 1.0 / w;
    }
    line("\n== Figure 2: per-LLM arrival rates over time (req/s) ==");
    line("llm \\ bucket: 24 buckets of 20s each");
    for (i, row) in rates.iter().enumerate().take(6) {
        let cells: Vec<String> =
            row.iter().map(|x| format!("{x:4.1}")).collect();
        line(&format!("llm{i:02}: {}", cells.join(" ")));
    }
    line("(llm06..15 elided; full data returned)");
    rates
}

// ---------------------------------------------------------------------------
// Figure 3: batch latency vs SM fraction
// ---------------------------------------------------------------------------

pub struct Fig3Row {
    pub sm_frac: f64,
    /// Relative prefill latency (vs 100% SMs) at bs=1 seqlen=128.
    pub prefill_rel: f64,
    /// Relative decode latency at bs ∈ {1, 8, 32}.
    pub decode_rel: [f64; 3],
}

pub fn fig3() -> Vec<Fig3Row> {
    let cm = CostModel::a100();
    let m = llama_spec("7b", 6.7);
    let base_p = cm.prefill_latency(&m, 128.0, 128.0, 1.0, 1);
    let base_d = [
        cm.decode_latency(&m, 1.0, 128.0, 1.0, 1),
        cm.decode_latency(&m, 8.0, 128.0, 1.0, 1),
        cm.decode_latency(&m, 32.0, 128.0, 1.0, 1),
    ];
    let mut out = Vec::new();
    line("\n== Figure 3: relative latency vs SM fraction (LLaMA-7B, seq 128) ==");
    line("sm%   prefill   decode-b1  decode-b8  decode-b32");
    for i in (3..=10).rev() {
        let f = i as f64 / 10.0;
        let row = Fig3Row {
            sm_frac: f,
            prefill_rel: cm.prefill_latency(&m, 128.0, 128.0, f, 1) / base_p,
            decode_rel: [
                cm.decode_latency(&m, 1.0, 128.0, f, 1) / base_d[0],
                cm.decode_latency(&m, 8.0, 128.0, f, 1) / base_d[1],
                cm.decode_latency(&m, 32.0, 128.0, f, 1) / base_d[2],
            ],
        };
        line(&format!(
            "{:>3.0}   {:>6.2}    {:>6.2}     {:>6.2}     {:>6.2}",
            f * 100.0,
            row.prefill_rel,
            row.decode_rel[0],
            row.decode_rel[1],
            row.decode_rel[2]
        ));
        out.push(row);
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 5: synthetic end-to-end (throughput + SLO attainment)
// ---------------------------------------------------------------------------

pub struct Fig5Point {
    pub alpha: f64,
    pub rate_scale: f64,
    pub system: &'static str,
    pub throughput: f64,
    /// SLO attainment at scales [2, 4, 6, 8, 10, 12, 16, 20].
    pub slo: Vec<f64>,
}

pub const SLO_SCALES: [f64; 8] = [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 16.0, 20.0];

pub fn fig5(alphas: &[f64], rate_scales: &[f64], duration: f64) -> Vec<Fig5Point> {
    let cluster = ClusterSpec::paper_testbed();
    let mut out = Vec::new();
    line("\n== Figure 5: synthetic workloads (19 LLMs, 32 GPUs) ==");
    line("alpha  scale  system     tpt     slo@4  slo@8  slo@12");
    for &alpha in alphas {
        for &rs in rate_scales {
            let max_rate = 20.0 * rs;
            let (specs, workloads, requests) =
                fig5_setup(alpha, max_rate, duration, 1234);
            let results = compare_three_systems(
                &specs, &workloads, &cluster, &requests, duration,
            );
            for r in results {
                let slo: Vec<f64> = SLO_SCALES
                    .iter()
                    .map(|s| r.eval.slo_attainment(*s))
                    .collect();
                line(&format!(
                    "{:<6.1} {:<6.1} {:<10} {:>7.2} {:>6.2} {:>6.2} {:>6.2}",
                    alpha,
                    rs,
                    r.name,
                    r.throughput(),
                    slo[1],
                    slo[3],
                    slo[5]
                ));
                out.push(Fig5Point {
                    alpha,
                    rate_scale: rs,
                    system: r.name,
                    throughput: r.throughput(),
                    slo,
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 6: cumulative rate distribution
// ---------------------------------------------------------------------------

pub fn fig6() -> Vec<(f64, Vec<f64>)> {
    let alphas = [0.7, 0.9, 1.3, 1.7, 2.1];
    let out = crate::bench::experiments::fig6_series(&alphas, 19);
    line("\n== Figure 6: cumulative rate share of top-k LLMs ==");
    line("alpha  top1   top4(~20%)  top8   top19");
    for (a, cum) in &out {
        line(&format!(
            "{:<6.1} {:>5.2}  {:>9.2}  {:>5.2}  {:>5.2}",
            a, cum[0], cum[3], cum[7], cum[18]
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 7: real (ChatLMSYS-like) workload
// ---------------------------------------------------------------------------

pub struct Fig7Point {
    pub avg_rate: f64,
    pub system: &'static str,
    pub throughput: f64,
    pub slo8: f64,
}

pub fn fig7(avg_rates: &[f64], duration: f64) -> Vec<Fig7Point> {
    // 16 LLMs on 32 GPUs, sizes sampled like the trace's mixed scales.
    let sizes = [
        6.7, 6.7, 6.7, 6.7, 6.7, 6.7, 6.7, 6.7, 13.0, 13.0, 13.0, 13.0,
        30.0, 30.0, 34.0, 65.0,
    ];
    let specs: Vec<ModelSpec> = sizes
        .iter()
        .enumerate()
        .map(|(i, p)| llama_spec(&format!("real-{i:02}"), *p))
        .collect();
    let cluster = ClusterSpec::paper_testbed();
    let mut out = Vec::new();
    line("\n== Figure 7: ChatLMSYS-like workload (16 LLMs, 32 GPUs) ==");
    line("avg_rate  system     tpt     slo@8");
    for &avg in avg_rates {
        let tspec = TraceSpec {
            n_llms: 16,
            avg_rate: avg,
            duration,
            period: duration / 2.0,
            depth: 0.6,
            seed: 77,
        };
        let (workloads, requests) = chatlmsys_like_trace(&tspec);
        let results = compare_three_systems(
            &specs, &workloads, &cluster, &requests, duration,
        );
        for r in results {
            line(&format!(
                "{:<9.1} {:<10} {:>7.2} {:>6.2}",
                avg,
                r.name,
                r.throughput(),
                r.eval.slo_attainment(8.0)
            ));
            out.push(Fig7Point {
                avg_rate: avg,
                system: r.name,
                throughput: r.throughput(),
                slo8: r.eval.slo_attainment(8.0),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 8: placement-algorithm ablation
// ---------------------------------------------------------------------------

pub struct Fig8Row {
    pub scenario: &'static str,
    pub ours: f64,
    pub greedy: f64,
}

pub fn fig8(duration: f64) -> Vec<Fig8Row> {
    let mut out = Vec::new();
    line("\n== Figure 8: placement ablation (ours vs memory-greedy) ==");
    line("scenario          ours-tpt  greedy-tpt  ratio");
    for (name, n_gpus, sizes, rates) in [
        (
            "8 GPUs, 4 LLMs",
            8usize,
            vec![6.7, 6.7, 13.0, 30.0],
            // 50% popular LLMs take >70% of traffic.
            vec![12.0, 9.0, 0.6, 0.3],
        ),
        (
            "16 GPUs, 7 LLMs",
            16,
            vec![6.7, 6.7, 6.7, 13.0, 13.0, 30.0, 34.0],
            vec![15.0, 12.0, 9.0, 6.0, 0.6, 0.3, 0.15],
        ),
    ] {
        let specs: Vec<ModelSpec> = sizes
            .iter()
            .enumerate()
            .map(|(i, p)| llama_spec(&format!("f8-{i}"), *p))
            .collect();
        let workloads: Vec<WorkloadSpec> =
            rates.iter().map(|r| WorkloadSpec::sharegpt(*r)).collect();
        let cluster = ClusterSpec::new(n_gpus / 8.max(1), 8.min(n_gpus));
        // The optimizer plans for the same tight memory the engine runs
        // with (kv_capacity_frac below).
        let est = Estimator::with_kv_frac(CostModel::a100(), 0.10);
        let n = specs.len();
        let streams: Vec<Vec<crate::workload::Request>> = {
            let mut rng = crate::util::Rng::new(5);
            workloads
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut sub = rng.fork(i as u64);
                    crate::workload::poisson_requests(i, s, duration, &mut sub)
                })
                .collect()
        };
        let requests = crate::workload::merge_streams(streams);
        let _ = n;

        let ours = muxserve_placement(&specs, &workloads, &cluster, &est)
            .expect("placement");
        // Memory-greedy on a fixed even mesh group (its own heuristic has
        // no group search).
        let group: Vec<usize> = vec![4; n_gpus / 4];
        let greedy = memory_greedy_placement(
            &specs, &workloads, &cluster, &est, &group,
        )
        .expect("greedy placement");

        // Memory-tight deployment (as in Figs. 9/10) so placement
        // decisions about which LLMs share a cache actually bind.
        let mut cfg = EngineConfig::muxserve();
        cfg.kv_capacity_frac = 0.10;
        let tpt = |p: &Placement| {
            run_system(p, &specs, &workloads, cfg, &requests, duration)
                .aggregate_throughput(&rates)
        };
        let (o, g) = (tpt(&ours), tpt(&greedy));
        line(&format!(
            "{:<17} {:>8.2} {:>10.2} {:>6.2}",
            name,
            o,
            g,
            o / g.max(1e-9)
        ));
        out.push(Fig8Row { scenario: name, ours: o, greedy: g });
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 9: ADBS vs FCFS vs Round-Robin
// ---------------------------------------------------------------------------

pub struct Fig9Row {
    pub policy: &'static str,
    pub throughput: f64,
    /// Per-LLM share of time-averaged block usage.
    pub usage_share: Vec<f64>,
    /// Per-LLM completion rate (req/s).
    pub per_llm_tpt: Vec<f64>,
}

pub fn fig9_scenario(
    sizes: &[f64],
    rates: &[f64],
    out_lens: &[f64],
    mesh_gpus: usize,
    duration: f64,
) -> Vec<Fig9Row> {
    let specs: Vec<ModelSpec> = sizes
        .iter()
        .enumerate()
        .map(|(i, p)| llama_spec(&format!("f9-{i}"), *p))
        .collect();
    let workloads: Vec<WorkloadSpec> = rates
        .iter()
        .zip(out_lens)
        .map(|(r, o)| WorkloadSpec {
            rate: *r,
            mean_prompt_len: o / 2.0,
            mean_output_len: *o,
            len_sigma: 0.6,
            tier_weight: 1.0,
        })
        .collect();
    let requests = {
        let mut rng = crate::util::Rng::new(21);
        let streams = workloads
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut sub = rng.fork(i as u64);
                crate::workload::poisson_requests(i, s, duration, &mut sub)
            })
            .collect();
        crate::workload::merge_streams(streams)
    };
    // All LLMs colocated on one mesh (the Fig. 9 colocation setting).
    let est = Estimator::new(CostModel::a100());
    let cands: Vec<ParallelCandidate> = specs
        .iter()
        .zip(&workloads)
        .map(|(s, w)| {
            let (tpt, batch) = est.single_llm(s, w, 0.5, mesh_gpus);
            ParallelCandidate { tp: mesh_gpus, sm: 0.5, batch, tpt,
                                meets_rate: true }
        })
        .collect();
    let placement = Placement {
        est_total: 0.0,
        units: vec![PlacementUnit {
            mesh_gpus,
            members: cands.into_iter().enumerate().collect(),
            role: Default::default(),
        }],
    };
    let cost = CostModel::a100();
    let mut out = Vec::new();
    // Memory-tight deployment (the paper's 4-GPU units run the cache at
    // full occupancy): 10% of the analytic KV capacity.
    let tight = |mut c: EngineConfig| {
        c.kv_capacity_frac = 0.10;
        c
    };
    for (name, cfg) in [
        ("FCFS", tight(EngineConfig::fcfs())),
        ("Round-Robin", tight(EngineConfig::round_robin())),
        ("ADBS", tight(EngineConfig::muxserve())),
    ] {
        let mut sim = Simulation::from_placement(
            &placement, &specs, &workloads, cfg, &cost,
        );
        let eval = sim.run(&requests, duration);
        let usage = sim.avg_block_usage();
        let total: f64 = usage.iter().sum::<f64>().max(1e-9);
        out.push(Fig9Row {
            policy: name,
            // Rate-weighted aggregate (§4.1): unfair cache sharing that
            // starves popular LLMs shows up here.
            throughput: eval.aggregate_throughput(rates),
            usage_share: usage.iter().map(|u| u / total).collect(),
            per_llm_tpt: (0..specs.len())
                .map(|i| eval.llm_throughput(i))
                .collect(),
        });
    }
    out
}

pub fn fig9(duration: f64) -> (Vec<Fig9Row>, Vec<Fig9Row>) {
    line("\n== Figure 9: cache usage + throughput by schedule policy ==");
    // (a) LLaMA-30B/13B/7B at rates 2:8:8 — avg request length 2:1:1.
    // Rates scaled into the contended regime (the paper's 4-GPU unit is
    // memory-saturated; our simulated pool is per-GPU identical).
    let a = fig9_scenario(
        &[30.0, 13.0, 6.7],
        &[4.0, 16.0, 16.0],
        &[400.0, 200.0, 200.0],
        4,
        duration,
    );
    line("(a) 30B/13B/7B, rates 2:8:8, lengths 2:1:1");
    print_fig9(&a);
    // (b) LLaMA-65B/30B at rates 1:8 — lengths 4:1.
    let b = fig9_scenario(
        &[65.0, 30.0],
        &[2.0, 12.0],
        &[480.0, 120.0],
        4,
        duration,
    );
    line("(b) 65B/30B, rates 1:8, lengths 4:1");
    print_fig9(&b);
    (a, b)
}

fn print_fig9(rows: &[Fig9Row]) {
    line("policy        tpt    usage-share           per-llm-tpt");
    for r in rows {
        let us: Vec<String> =
            r.usage_share.iter().map(|x| format!("{x:.2}")).collect();
        let pt: Vec<String> =
            r.per_llm_tpt.iter().map(|x| format!("{x:.1}")).collect();
        line(&format!(
            "{:<12} {:>5.2}   [{}]   [{}]",
            r.policy,
            r.throughput,
            us.join(", "),
            pt.join(", ")
        ));
    }
}

// ---------------------------------------------------------------------------
// Figure 10: unified-resource-manager ablation
// ---------------------------------------------------------------------------

pub struct Fig10Point {
    pub alpha: f64,
    pub stage: &'static str,
    pub throughput: f64,
    pub slo8: f64,
}

pub fn fig10(alphas: &[f64], duration: f64) -> Vec<Fig10Point> {
    let sizes = [6.7, 6.7, 13.0, 13.0];
    let specs: Vec<ModelSpec> = sizes
        .iter()
        .enumerate()
        .map(|(i, p)| llama_spec(&format!("f10-{i}"), *p))
        .collect();
    let est = Estimator::new(CostModel::a100());
    let cost = CostModel::a100();
    let mut out = Vec::new();
    line("\n== Figure 10: resource manager ablation (4 LLMs, 4 GPUs) ==");
    line("alpha  stage             tpt    slo@8");
    for &alpha in alphas {
        let (workloads, requests) =
            synthetic_workload(4, alpha, 15.0, duration, 31);
        // The ablation isolates the resource manager, so the placement is
        // fixed: all four LLMs colocated on one 4-GPU mesh.
        let placement = Placement {
            est_total: 0.0,
            units: vec![PlacementUnit {
                mesh_gpus: 4,
                members: specs
                    .iter()
                    .zip(&workloads)
                    .enumerate()
                    .map(|(i, (sp, w))| {
                        let (tpt, batch) = est.single_llm(sp, w, 0.5, 4);
                        (i, ParallelCandidate {
                            tp: 4,
                            sm: 0.5,
                            batch,
                            tpt,
                            meets_rate: true,
                        })
                    })
                    .collect(),
                role: Default::default(),
            }],
        };
        let tight = |mut c: EngineConfig| {
            c.kv_capacity_frac = 0.08;
            c
        };
        for (stage, cfg) in [
            ("temporal", tight(EngineConfig::temporal())),
            ("+compute-mgmt", tight(EngineConfig::compute_mgmt_only())),
            ("+memory-mgmt", tight(EngineConfig::muxserve())),
        ] {
            let mut sim = Simulation::from_placement(
                &placement, &specs, &workloads, cfg, &cost,
            );
            let eval = sim.run(&requests, duration);
            let rates: Vec<f64> = workloads.iter().map(|w| w.rate).collect();
            let tpt = eval.aggregate_throughput(&rates);
            let slo8 = eval.slo_attainment(8.0);
            line(&format!(
                "{:<6.1} {:<17} {:>5.1} {:>6.2}",
                alpha, stage, tpt, slo8
            ));
            out.push(Fig10Point { alpha, stage, throughput: tpt, slo8 });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 11 (Appendix A.1): P99 latency / TPOT / TTFT
// ---------------------------------------------------------------------------

pub struct Fig11Row {
    pub alpha: f64,
    pub system: &'static str,
    pub p99_latency: f64,
    pub p99_tpot: f64,
    pub p99_ttft: f64,
}

pub fn fig11(alphas: &[f64], duration: f64) -> Vec<Fig11Row> {
    let cluster = ClusterSpec::paper_testbed();
    let mut out = Vec::new();
    line("\n== Figure 11: P99 latency / TPOT / TTFT (synthetic) ==");
    line("alpha  system     p99-lat(s)  p99-tpot(s)  p99-ttft(s)");
    for &alpha in alphas {
        let (specs, workloads, requests) =
            fig5_setup(alpha, 20.0, duration, 99);
        let results = compare_three_systems(
            &specs, &workloads, &cluster, &requests, duration,
        );
        for r in results {
            let row = Fig11Row {
                alpha,
                system: r.name,
                p99_latency: r.eval.latency_summary().p99(),
                p99_tpot: r.eval.tpot_summary().p99(),
                p99_ttft: r.eval.ttft_summary().p99(),
            };
            line(&format!(
                "{:<6.1} {:<10} {:>10.2} {:>12.4} {:>12.2}",
                alpha, row.system, row.p99_latency, row.p99_tpot, row.p99_ttft
            ));
            out.push(row);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 12 (Appendix A.2): throughput estimator validation
// ---------------------------------------------------------------------------

pub struct Fig12Row {
    pub unit: String,
    pub predicted: f64,
    pub simulated: f64,
}

pub fn fig12(duration: f64) -> Vec<Fig12Row> {
    let est = Estimator::new(CostModel::a100());
    let cost = CostModel::a100();
    let mut out = Vec::new();
    line("\n== Figure 12: Eq.3 estimator vs simulation ==");
    line("unit                          predicted  simulated  err%");
    for (name, sizes, rates, mesh) in [
        ("7B+7B on 1 GPU", vec![6.7, 6.7], vec![1.0, 0.5], 1usize),
        ("7B+13B on 2 GPUs", vec![6.7, 13.0], vec![2.0, 0.5], 2),
        ("30B+7B on 4 GPUs", vec![30.0, 6.7], vec![0.5, 3.0], 4),
    ] {
        let specs: Vec<ModelSpec> = sizes
            .iter()
            .enumerate()
            .map(|(i, p)| llama_spec(&format!("f12-{i}"), *p))
            .collect();
        let workloads: Vec<WorkloadSpec> =
            rates.iter().map(|r| WorkloadSpec::sharegpt(*r)).collect();
        let members: Vec<UnitMember> = specs
            .iter()
            .zip(&workloads)
            .map(|(s, w)| UnitMember {
                spec: s.clone(),
                workload: w.clone(),
                prefill_sm: 0.6,
                decode_sm: 0.6,
                tp: mesh,
            })
            .collect();
        let predicted = est.unit_estimate(&members, mesh).total;

        let placement = Placement {
            est_total: predicted,
            units: vec![PlacementUnit {
                mesh_gpus: mesh,
                members: (0..specs.len())
                    .map(|i| {
                        (i, ParallelCandidate {
                            tp: mesh,
                            sm: 0.6,
                            batch: 1.0,
                            tpt: 0.0,
                            meets_rate: true,
                        })
                    })
                    .collect(),
                role: Default::default(),
            }],
        };
        let requests = {
            let mut rng = crate::util::Rng::new(3);
            let streams = workloads
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut sub = rng.fork(i as u64);
                    crate::workload::poisson_requests(i, s, duration, &mut sub)
                })
                .collect();
            crate::workload::merge_streams(streams)
        };
        let mut sim = Simulation::from_placement(
            &placement, &specs, &workloads, EngineConfig::muxserve(), &cost,
        );
        let eval = sim.run(&requests, duration);
        let simulated = eval.total_throughput();
        line(&format!(
            "{:<29} {:>9.2} {:>10.2} {:>5.0}%",
            name,
            predicted,
            simulated,
            ((predicted - simulated) / simulated.max(1e-9) * 100.0).abs()
        ));
        out.push(Fig12Row { unit: name.to_string(), predicted, simulated });
    }
    out
}
