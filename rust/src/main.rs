//! MuxServe CLI — leader entrypoint.

fn main() -> anyhow::Result<()> {
    muxserve::cli::main()
}
