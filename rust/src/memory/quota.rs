//! Token-block quota accounting (§3.3): R(·,·) is token-block usage,
//! normalized by request rate; ADBS assigns each LLM a quota and adapts it
//! periodically by transferring blocks from low- to high-utilization LLMs.

use super::KvError;

/// Counting model of the unified KV cache: per-LLM quota and usage over a
/// shared pool of `total_blocks` head-wise blocks.
#[derive(Clone, Debug)]
pub struct QuotaCache {
    total_blocks: usize,
    quota: Vec<usize>,
    used: Vec<usize>,
    /// Peak usage since the last adaptation round (demand signal).
    peak: Vec<usize>,
    /// Demand that could not be admitted since last adaptation.
    denied: Vec<usize>,
}

impl QuotaCache {
    /// Initial quota split proportional to `weights` (the paper seeds this
    /// with rate-and-scale-normalized shares; see `init_weights`).
    pub fn new(total_blocks: usize, weights: &[f64]) -> Self {
        let n = weights.len();
        if n == 0 {
            // An empty unit (mesh with no LLMs placed) holds no quotas.
            return QuotaCache {
                total_blocks,
                quota: vec![],
                used: vec![],
                peak: vec![],
                denied: vec![],
            };
        }
        let wsum: f64 = weights.iter().sum();
        let mut quota: Vec<usize> = weights
            .iter()
            .map(|w| {
                ((w / wsum) * total_blocks as f64).floor().max(1.0) as usize
            })
            .collect();
        // Fix rounding so quotas sum to exactly the pool size. If the pool
        // is smaller than the LLM count the floor of 1 block each cannot
        // be reduced further — quotas may then exceed the pool, which is
        // safe because allocation always checks the pool too.
        let mut diff = total_blocks as i64
            - quota.iter().sum::<usize>() as i64;
        let mut i = 0;
        while diff != 0 && i < 4 * n * (diff.unsigned_abs() as usize + 1) {
            if diff > 0 {
                quota[i % n] += 1;
                diff -= 1;
            } else if quota[i % n] > 1 {
                quota[i % n] -= 1;
                diff += 1;
            }
            i += 1;
        }
        QuotaCache {
            total_blocks,
            quota,
            used: vec![0; n],
            peak: vec![0; n],
            denied: vec![0; n],
        }
    }

    /// Paper-faithful initial weights: token-block demand of an LLM is its
    /// request rate × mean tokens × blocks-per-token, i.e. proportional to
    /// rate × layers × heads (scale) — "normalized to account for varying
    /// LLM scales and popularity".
    pub fn init_weights(
        rates: &[f64],
        blocks_per_req: &[f64],
    ) -> Vec<f64> {
        rates
            .iter()
            .zip(blocks_per_req)
            .map(|(r, b)| (r * b).max(1e-9))
            .collect()
    }

    pub fn n_llms(&self) -> usize {
        self.quota.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn quota(&self, llm: usize) -> usize {
        self.quota[llm]
    }

    pub fn used(&self, llm: usize) -> usize {
        self.used[llm]
    }

    pub fn total_used(&self) -> usize {
        self.used.iter().sum()
    }

    pub fn free_in_pool(&self) -> usize {
        self.total_blocks - self.total_used()
    }

    /// Can `n` blocks be allocated for `llm` right now?
    pub fn can_alloc(&self, llm: usize, n: usize) -> Result<(), KvError> {
        if self.used[llm] + n > self.quota[llm] {
            return Err(KvError::QuotaExceeded);
        }
        if self.total_used() + n > self.total_blocks {
            return Err(KvError::PoolExhausted);
        }
        Ok(())
    }

    /// Allocate, recording denial pressure for the adaptor on failure.
    pub fn alloc(&mut self, llm: usize, n: usize) -> Result<(), KvError> {
        match self.can_alloc(llm, n) {
            Ok(()) => {
                self.used[llm] += n;
                self.peak[llm] = self.peak[llm].max(self.used[llm]);
                Ok(())
            }
            Err(e) => {
                self.denied[llm] += n;
                Err(e)
            }
        }
    }

    /// Allocate checking only the shared pool, ignoring the per-LLM quota
    /// (the Round-Robin baseline of Fig. 9: first-come-first-served cache).
    pub fn alloc_pool_only(&mut self, llm: usize, n: usize) -> Result<(), KvError> {
        if self.total_used() + n > self.total_blocks {
            self.denied[llm] += n;
            return Err(KvError::PoolExhausted);
        }
        self.used[llm] += n;
        self.peak[llm] = self.peak[llm].max(self.used[llm]);
        Ok(())
    }

    pub fn free(&mut self, llm: usize, n: usize) {
        assert!(self.used[llm] >= n, "free {n} > used {}", self.used[llm]);
        self.used[llm] -= n;
    }

    /// Utilization of an LLM's quota in [0, 1].
    pub fn utilization(&self, llm: usize) -> f64 {
        if self.quota[llm] == 0 {
            return 1.0;
        }
        self.used[llm] as f64 / self.quota[llm] as f64
    }

    /// Periodic quota adaptation (§3.3): identify low-utilization LLMs and
    /// transfer their surplus quota to LLMs with unmet demand. `demand[i]`
    /// is the target block count (peak usage + denied since last round).
    pub fn adapt(&mut self) {
        let n = self.quota.len();
        let demand: Vec<usize> = (0..n)
            .map(|i| self.peak[i] + self.denied[i])
            .collect();
        // Surplus: quota above max(demand, current usage) with 10% slack.
        let mut surplus_total = 0usize;
        let mut deficit: Vec<usize> = vec![0; n];
        let mut deficit_total = 0usize;
        for i in 0..n {
            let want = ((demand[i] as f64 * 1.1).ceil() as usize)
                .max(self.used[i])
                .max(1);
            if self.quota[i] > want {
                surplus_total += self.quota[i] - want;
                self.quota[i] = want;
            } else if want > self.quota[i] {
                deficit[i] = want - self.quota[i];
                deficit_total += deficit[i];
            }
        }
        if deficit_total == 0 {
            // No pressure: return surplus evenly so the pool stays covered.
            let share = surplus_total / n.max(1);
            for q in self.quota.iter_mut() {
                *q += share;
            }
            let rem = surplus_total - share * n;
            for q in self.quota.iter_mut().take(rem) {
                *q += 1;
            }
        } else {
            // Distribute surplus proportionally to deficit.
            let mut given = 0usize;
            for i in 0..n {
                let g = (surplus_total as f64 * deficit[i] as f64
                    / deficit_total as f64)
                    .floor() as usize;
                self.quota[i] += g;
                given += g;
            }
            // Round-off leftovers to the largest deficit.
            if surplus_total > given {
                if let Some((imax, _)) = deficit
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, d)| **d)
                {
                    self.quota[imax] += surplus_total - given;
                }
            }
        }
        self.peak = self.used.clone();
        self.denied = vec![0; n];
        debug_assert!(
            self.quota.iter().sum::<usize>() >= self.total_blocks.min(n),
        );
    }

    /// Fairness measure |R_i - R_j| of Eq. 2: normalized block usage spread.
    /// `norm[i]` is each LLM's normalizer (rate × blocks per request).
    pub fn fairness_spread(&self, norm: &[f64]) -> f64 {
        let rs: Vec<f64> = (0..self.n_llms())
            .map(|i| self.used[i] as f64 / norm[i].max(1e-9))
            .collect();
        let max = rs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = rs.iter().cloned().fold(f64::INFINITY, f64::min);
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quotas_sum_to_pool() {
        let q = QuotaCache::new(1000, &[3.0, 1.0, 1.0]);
        let total: usize = (0..3).map(|i| q.quota(i)).sum();
        assert_eq!(total, 1000);
        assert!(q.quota(0) > q.quota(1));
    }

    #[test]
    fn alloc_respects_quota() {
        let mut q = QuotaCache::new(100, &[1.0, 1.0]);
        assert_eq!(q.quota(0), 50);
        assert!(q.alloc(0, 50).is_ok());
        assert_eq!(q.alloc(0, 1), Err(KvError::QuotaExceeded));
        q.free(0, 10);
        assert!(q.alloc(0, 10).is_ok());
    }

    #[test]
    fn adapt_moves_blocks_to_pressured_llm() {
        let mut q = QuotaCache::new(100, &[1.0, 1.0]);
        // LLM 0 idle; LLM 1 fills its quota and gets denied.
        assert!(q.alloc(1, 50).is_ok());
        assert_eq!(q.alloc(1, 30), Err(KvError::QuotaExceeded));
        q.adapt();
        assert!(
            q.quota(1) > 60,
            "quota after adapt: {} (expected growth)",
            q.quota(1)
        );
        assert!(q.quota(0) < 50);
        // Now the denied allocation fits.
        assert!(q.alloc(1, 30).is_ok());
    }

    #[test]
    fn adapt_never_strands_used_blocks() {
        let mut q = QuotaCache::new(64, &[1.0, 1.0, 1.0, 1.0]);
        assert!(q.alloc(2, 10).is_ok());
        q.adapt();
        assert!(q.quota(2) >= q.used(2));
    }

    #[test]
    fn fairness_spread_zero_when_balanced() {
        let mut q = QuotaCache::new(100, &[1.0, 1.0]);
        q.alloc(0, 20).unwrap();
        q.alloc(1, 20).unwrap();
        assert!(q.fairness_spread(&[1.0, 1.0]) < 1e-9);
        q.alloc(0, 20).unwrap();
        assert!(q.fairness_spread(&[1.0, 1.0]) > 0.0);
    }

    #[test]
    fn pool_exhaustion_detected() {
        let mut q = QuotaCache::new(10, &[1.0]);
        assert!(q.alloc(0, 10).is_ok());
        assert_eq!(q.alloc(0, 1), Err(KvError::QuotaExceeded));
    }
}
