//! Pluggable eviction for the device block pool.
//!
//! When the pool is under pressure the engine asks a policy which cold
//! decode context to push down the hierarchy (host tier) or recompute
//! later. Policies rank [`EvictCandidate`]s — snapshots of a context's
//! size, recency, frequency, and recompute cost — and are deterministic:
//! ties always break on the lowest request id, so simulations replay
//! bit-identically.
//!
//! Built-ins:
//!
//! * [`Lru`] — evict the least-recently-used context.
//! * [`Slru`] — segmented LRU: contexts touched at most once sit in a
//!   probationary segment and are evicted before any multiply-touched
//!   (protected) context; LRU within each segment.
//! * [`Gdsf`] — Greedy-Dual-Size-Frequency: priority is
//!   `L + freq × recompute_cost / size`, so big contexts that are cheap
//!   to rebuild go first and small expensive ones are protected. The
//!   recompute cost is the same prefill pricing the migration planner
//!   uses for its KV-copy-vs-recompute decision.

/// Which eviction policy an engine runs (`None` disables cache
/// management entirely — no prefix sharing, no host tier — reproducing
/// the pre-cache engine exactly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionKind {
    None,
    Lru,
    Slru,
    Gdsf,
}

impl EvictionKind {
    pub fn parse(s: &str) -> Option<EvictionKind> {
        match s {
            "none" => Some(EvictionKind::None),
            "lru" => Some(EvictionKind::Lru),
            "slru" => Some(EvictionKind::Slru),
            "gdsf" => Some(EvictionKind::Gdsf),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvictionKind::None => "none",
            EvictionKind::Lru => "lru",
            EvictionKind::Slru => "slru",
            EvictionKind::Gdsf => "gdsf",
        }
    }

    /// Every kind, `none` first (CLI/help and bench grids iterate this).
    pub fn all() -> [EvictionKind; 4] {
        [
            EvictionKind::None,
            EvictionKind::Lru,
            EvictionKind::Slru,
            EvictionKind::Gdsf,
        ]
    }

    /// The actual policies (everything but `none`).
    pub fn policies() -> [EvictionKind; 3] {
        [EvictionKind::Lru, EvictionKind::Slru, EvictionKind::Gdsf]
    }
}

/// Snapshot of one evictable decode context, as the engine sees it at the
/// moment pressure forces a victim choice.
#[derive(Clone, Copy, Debug)]
pub struct EvictCandidate {
    /// Request id (deterministic tie-break key).
    pub id: u64,
    /// Device blocks the context would release (private blocks only —
    /// shared prefix blocks stay resident for their other referents).
    pub blocks: usize,
    /// Simulation time of the context's last scheduled job.
    pub last_use: f64,
    /// How many times the context has been scheduled (admission counts
    /// as the first touch).
    pub freq: u32,
    /// Seconds to rebuild the context's KV state by re-running prefill —
    /// the same pricing `coordinator/migration.rs` uses.
    pub recompute_s: f64,
}

/// Victim choice under memory pressure. `pick` is handed a non-empty
/// candidate slice and returns the index of the context to evict.
/// Implementations must be deterministic (tie-break on `id`).
///
/// `Send` because the policy travels inside its `UnitSim` when the
/// sharded simulator moves units onto worker threads between
/// coordinator barriers.
pub trait EvictionPolicy: Send {
    fn kind(&self) -> EvictionKind;
    fn pick(&mut self, candidates: &[EvictCandidate]) -> usize;
}

/// Least-recently-used.
#[derive(Clone, Copy, Debug, Default)]
pub struct Lru;

impl EvictionPolicy for Lru {
    fn kind(&self) -> EvictionKind {
        EvictionKind::Lru
    }

    fn pick(&mut self, candidates: &[EvictCandidate]) -> usize {
        min_index(candidates, |c| (c.last_use, c.id))
    }
}

/// Segmented LRU: probationary (freq <= 1) before protected.
#[derive(Clone, Copy, Debug, Default)]
pub struct Slru;

impl EvictionPolicy for Slru {
    fn kind(&self) -> EvictionKind {
        EvictionKind::Slru
    }

    fn pick(&mut self, candidates: &[EvictCandidate]) -> usize {
        // Segment key first: probationary (0) sorts before protected (1),
        // then LRU within the segment.
        min_index(candidates, |c| {
            let segment = u32::from(c.freq > 1);
            ((segment, c.last_use), c.id)
        })
    }
}

/// Greedy-Dual-Size-Frequency with the classic aging term `l`: every
/// eviction raises the floor to the victim's priority, so long-idle
/// contexts eventually lose protection no matter their cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct Gdsf {
    l: f64,
}

impl Gdsf {
    fn priority(&self, c: &EvictCandidate) -> f64 {
        self.l + c.freq as f64 * c.recompute_s / c.blocks.max(1) as f64
    }
}

impl EvictionPolicy for Gdsf {
    fn kind(&self) -> EvictionKind {
        EvictionKind::Gdsf
    }

    fn pick(&mut self, candidates: &[EvictCandidate]) -> usize {
        let i = min_index(candidates, |c| (self.priority(c), c.id));
        self.l = self.priority(&candidates[i]);
        i
    }
}

/// Build a boxed policy for `kind`; `None` for [`EvictionKind::None`].
pub fn build_policy(
    kind: EvictionKind,
) -> Option<Box<dyn EvictionPolicy>> {
    match kind {
        EvictionKind::None => None,
        EvictionKind::Lru => Some(Box::new(Lru)),
        EvictionKind::Slru => Some(Box::new(Slru)),
        EvictionKind::Gdsf => Some(Box::<Gdsf>::default()),
    }
}

/// Index of the minimum by key. Keys never contain NaN (times and
/// prices are finite), so `PartialOrd` is total here; callers embed
/// `id` in the key so ties break deterministically.
fn min_index<K: PartialOrd + Copy>(
    candidates: &[EvictCandidate],
    key: impl Fn(&EvictCandidate) -> K,
) -> usize {
    debug_assert!(!candidates.is_empty());
    let mut best = 0;
    let mut best_key = key(&candidates[0]);
    for (i, c) in candidates.iter().enumerate().skip(1) {
        let k = key(c);
        if k < best_key {
            best = i;
            best_key = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(
        id: u64,
        blocks: usize,
        last_use: f64,
        freq: u32,
        recompute_s: f64,
    ) -> EvictCandidate {
        EvictCandidate { id, blocks, last_use, freq, recompute_s }
    }

    #[test]
    fn kinds_parse_round_trip() {
        for k in EvictionKind::all() {
            assert_eq!(EvictionKind::parse(k.name()), Some(k));
        }
        assert_eq!(EvictionKind::parse("fifo"), None);
        assert_eq!(EvictionKind::policies().len(), 3);
        assert!(build_policy(EvictionKind::None).is_none());
        for k in EvictionKind::policies() {
            assert_eq!(build_policy(k).unwrap().kind(), k);
        }
    }

    #[test]
    fn lru_picks_oldest_with_id_tie_break() {
        let mut p = Lru;
        let cs = [
            cand(7, 10, 5.0, 3, 1.0),
            cand(2, 10, 1.0, 3, 1.0),
            cand(9, 10, 1.0, 3, 1.0),
        ];
        // Oldest last_use wins; between the two at t=1.0 the lower id.
        assert_eq!(p.pick(&cs), 1);
    }

    #[test]
    fn slru_evicts_probationary_before_protected() {
        let mut p = Slru;
        let cs = [
            // Protected (freq > 1) but much older...
            cand(1, 10, 0.0, 5, 1.0),
            // ...still outlives this fresher one-touch context.
            cand(2, 10, 9.0, 1, 1.0),
        ];
        assert_eq!(p.pick(&cs), 1);
        // With only protected contexts it degrades to LRU.
        let protected = [
            cand(1, 10, 4.0, 2, 1.0),
            cand(2, 10, 3.0, 2, 1.0),
        ];
        assert_eq!(p.pick(&protected), 1);
    }

    #[test]
    fn gdsf_prefers_big_cheap_contexts_and_ages() {
        let mut p = Gdsf::default();
        let cs = [
            // Small and expensive to recompute: protected.
            cand(1, 4, 0.0, 1, 8.0),
            // Huge and cheap: priority 1 * 0.1 / 100, evicted first.
            cand(2, 100, 0.0, 1, 0.1),
        ];
        assert_eq!(p.pick(&cs), 1);
        // The floor `l` rose to the victim's priority.
        assert!(p.l > 0.0);
        let floor = p.l;
        assert_eq!(p.pick(&cs), 1);
        assert!(p.l >= floor);
    }
}
