//! Concrete block-id allocator for the real PJRT serving path.
//!
//! The compiled JAX graphs address the shared K/V pools through block
//! tables; this allocator hands out actual pool slots. It is the rust-side
//! twin of the paper's memory-manager process (implemented there in C++
//! over CUDA IPC; here the pool lives in host literals fed to PJRT).
//!
//! Blocks are **refcounted** so shared prompt prefixes can be referenced
//! by many requests of the same owner: [`BlockAllocator::retain`] adds a
//! reference, [`BlockAllocator::free_blocks`] drops one, and a block
//! returns to the free list exactly once — when its last reference drops.
//! Copy-on-write is the caller's contract: shared blocks are never
//! written past their prefix; divergent suffixes allocate fresh blocks.

use super::KvError;

/// Free-list allocator over `n_blocks` pool slots with per-owner tracking
/// and per-block refcounts.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    free: Vec<u32>,
    owner: Vec<Option<u32>>,
    /// References outstanding per block; 0 ⇔ the block is on the free list.
    refcount: Vec<u32>,
    /// Physical blocks held per owner (a block counts once however many
    /// references it carries).
    allocated_per_owner: Vec<usize>,
}

impl BlockAllocator {
    pub fn new(n_blocks: usize, n_owners: usize) -> Self {
        BlockAllocator {
            // LIFO free list: recently-freed (cache-warm) blocks reused first.
            free: (0..n_blocks as u32).rev().collect(),
            owner: vec![None; n_blocks],
            refcount: vec![0; n_blocks],
            allocated_per_owner: vec![0; n_owners],
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.owner.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn used_by(&self, owner: usize) -> usize {
        self.allocated_per_owner[owner]
    }

    /// Outstanding references on a block (0 = free).
    pub fn refcount(&self, block: u32) -> u32 {
        self.refcount[block as usize]
    }

    /// Allocate `n` blocks for `owner` with one reference each; returns
    /// their pool ids, or `KvError::PoolExhausted` if the pool cannot
    /// satisfy the request (all-or-nothing — a failed call mutates
    /// nothing).
    pub fn alloc(
        &mut self,
        owner: usize,
        n: usize,
    ) -> Result<Vec<u32>, KvError> {
        if self.free.len() < n {
            return Err(KvError::PoolExhausted);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.free.pop().unwrap();
            debug_assert!(self.owner[b as usize].is_none());
            debug_assert_eq!(self.refcount[b as usize], 0);
            self.owner[b as usize] = Some(owner as u32);
            self.refcount[b as usize] = 1;
            out.push(b);
        }
        self.allocated_per_owner[owner] += n;
        Ok(out)
    }

    /// Add one reference to each of `blocks` (prefix sharing: a new
    /// request pointing its block table at an existing prefix). Every
    /// block must be live and owned by `owner` — KV sharing never crosses
    /// LLMs. All-or-nothing: on `KvError::NotOwned` no refcount changes.
    pub fn retain(
        &mut self,
        owner: usize,
        blocks: &[u32],
    ) -> Result<(), KvError> {
        for &b in blocks {
            let bi = b as usize;
            if bi >= self.owner.len()
                || self.owner[bi] != Some(owner as u32)
            {
                return Err(KvError::NotOwned);
            }
        }
        for &b in blocks {
            self.refcount[b as usize] += 1;
        }
        Ok(())
    }

    /// Drop one reference from each of `blocks`; a block returns to the
    /// pool when its last reference drops. A double free (or a foreign
    /// block, or more drops in one batch than a block has references) is
    /// `KvError::NotOwned` at this public boundary — validated up front,
    /// so a failed call mutates nothing.
    pub fn free_blocks(
        &mut self,
        owner: usize,
        blocks: &[u32],
    ) -> Result<(), KvError> {
        // Validate the whole batch (counting duplicates within it) before
        // touching any state.
        let mut sorted: Vec<u32> = blocks.to_vec();
        sorted.sort_unstable();
        let mut i = 0;
        while i < sorted.len() {
            let b = sorted[i] as usize;
            let mut j = i + 1;
            while j < sorted.len() && sorted[j] as usize == b {
                j += 1;
            }
            let drops = (j - i) as u32;
            if b >= self.owner.len()
                || self.owner[b] != Some(owner as u32)
                || self.refcount[b] < drops
            {
                return Err(KvError::NotOwned);
            }
            i = j;
        }
        let mut released = 0usize;
        for &b in blocks {
            let bi = b as usize;
            self.refcount[bi] -= 1;
            if self.refcount[bi] == 0 {
                self.owner[bi] = None;
                self.free.push(b);
                released += 1;
            }
        }
        self.allocated_per_owner[owner] -= released;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proplite, Rng};

    #[test]
    fn alloc_free_round_trip() {
        let mut a = BlockAllocator::new(16, 2);
        let b0 = a.alloc(0, 5).unwrap();
        let b1 = a.alloc(1, 5).unwrap();
        assert_eq!(a.n_free(), 6);
        assert_eq!(a.used_by(0), 5);
        // No overlap between owners.
        assert!(b0.iter().all(|x| !b1.contains(x)));
        a.free_blocks(0, &b0).unwrap();
        assert_eq!(a.n_free(), 11);
        assert_eq!(a.used_by(0), 0);
    }

    #[test]
    fn all_or_nothing() {
        let mut a = BlockAllocator::new(4, 1);
        assert_eq!(a.alloc(0, 5), Err(KvError::PoolExhausted));
        assert_eq!(a.n_free(), 4);
        assert!(a.alloc(0, 4).is_ok());
        assert_eq!(a.alloc(0, 1), Err(KvError::PoolExhausted));
    }

    #[test]
    fn double_free_is_an_error_not_a_panic() {
        let mut a = BlockAllocator::new(4, 1);
        let b = a.alloc(0, 2).unwrap();
        a.free_blocks(0, &b).unwrap();
        assert_eq!(a.free_blocks(0, &b), Err(KvError::NotOwned));
        // The failed call corrupted nothing.
        assert_eq!(a.n_free(), 4);
        assert_eq!(a.used_by(0), 0);
    }

    #[test]
    fn foreign_free_rejected_without_mutation() {
        let mut a = BlockAllocator::new(8, 2);
        let b0 = a.alloc(0, 3).unwrap();
        assert_eq!(a.free_blocks(1, &b0), Err(KvError::NotOwned));
        assert_eq!(a.used_by(0), 3);
        assert_eq!(a.n_free(), 5);
        // A batch mixing valid and invalid blocks must also mutate nothing.
        let mut mixed = b0.clone();
        mixed.push(99); // out of range
        assert_eq!(a.free_blocks(0, &mixed), Err(KvError::NotOwned));
        assert_eq!(a.used_by(0), 3);
    }

    #[test]
    fn shared_blocks_freed_exactly_once() {
        let mut a = BlockAllocator::new(8, 1);
        let prefix = a.alloc(0, 4).unwrap();
        // Two more requests reference the same prefix.
        a.retain(0, &prefix).unwrap();
        a.retain(0, &prefix).unwrap();
        assert_eq!(a.refcount(prefix[0]), 3);
        assert_eq!(a.used_by(0), 4, "shared blocks count physically once");
        // First two releases keep the blocks live...
        a.free_blocks(0, &prefix).unwrap();
        a.free_blocks(0, &prefix).unwrap();
        assert_eq!(a.n_free(), 4);
        assert_eq!(a.used_by(0), 4);
        // ...the last reference returns them to the pool.
        a.free_blocks(0, &prefix).unwrap();
        assert_eq!(a.n_free(), 8);
        assert_eq!(a.used_by(0), 0);
        // And one drop beyond the refcount is an error, not a panic.
        assert_eq!(a.free_blocks(0, &prefix), Err(KvError::NotOwned));
    }

    #[test]
    fn retain_rejects_foreign_and_free_blocks() {
        let mut a = BlockAllocator::new(8, 2);
        let b0 = a.alloc(0, 2).unwrap();
        assert_eq!(a.retain(1, &b0), Err(KvError::NotOwned));
        a.free_blocks(0, &b0).unwrap();
        assert_eq!(a.retain(0, &b0), Err(KvError::NotOwned));
    }

    /// Property: any interleaving of allocs/retains/frees conserves
    /// blocks, never double-allocates, and restores full capacity once
    /// every reference is dropped.
    #[test]
    fn prop_alloc_free_conservation() {
        proplite::check(200, |rng: &mut Rng| {
            let n_blocks = rng.range(1, 64) as usize;
            let n_owners = rng.range(1, 4) as usize;
            let mut a = BlockAllocator::new(n_blocks, n_owners);
            // Outstanding references: (owner, blocks). A retain pushes a
            // second entry for the same ids, so every entry is exactly one
            // pending free_blocks call.
            let mut held: Vec<(usize, Vec<u32>)> = Vec::new();
            for _ in 0..rng.range(1, 50) {
                let roll = rng.f64();
                if roll < 0.5 || held.is_empty() {
                    let owner = rng.below(n_owners);
                    let want = rng.range(1, 8) as usize;
                    if let Ok(blocks) = a.alloc(owner, want) {
                        crate::prop_assert!(
                            blocks.len() == want,
                            "short allocation"
                        );
                        held.push((owner, blocks));
                    }
                } else if roll < 0.7 {
                    // Share an existing holding (prefix-style retain).
                    let i = rng.below(held.len());
                    let (owner, blocks) = held[i].clone();
                    crate::prop_assert!(
                        a.retain(owner, &blocks).is_ok(),
                        "retain of live blocks failed"
                    );
                    held.push((owner, blocks));
                } else {
                    let i = rng.below(held.len());
                    let (owner, blocks) = held.swap_remove(i);
                    crate::prop_assert!(
                        a.free_blocks(owner, &blocks).is_ok(),
                        "free of held blocks failed"
                    );
                }
                // Invariant: distinct held blocks + free == total.
                let mut distinct: Vec<u32> = held
                    .iter()
                    .flat_map(|(_, b)| b.iter().copied())
                    .collect();
                distinct.sort();
                distinct.dedup();
                crate::prop_assert!(
                    distinct.len() + a.n_free() == n_blocks,
                    "leak: held={} free={}",
                    distinct.len(),
                    a.n_free()
                );
                // Refcounts mirror the outstanding references exactly.
                for &b in &distinct {
                    let refs = held
                        .iter()
                        .filter(|(_, bl)| bl.contains(&b))
                        .count() as u32;
                    crate::prop_assert!(
                        a.refcount(b) == refs,
                        "block {b}: refcount {} != {refs} holders",
                        a.refcount(b)
                    );
                }
            }
            for (owner, blocks) in held.drain(..) {
                crate::prop_assert!(
                    a.free_blocks(owner, &blocks).is_ok(),
                    "final free failed"
                );
            }
            crate::prop_assert!(
                a.n_free() == n_blocks,
                "capacity not restored"
            );
            Ok(())
        });
    }
}
