//! Concrete block-id allocator for the real PJRT serving path.
//!
//! The compiled JAX graphs address the shared K/V pools through block
//! tables; this allocator hands out actual pool slots. It is the rust-side
//! twin of the paper's memory-manager process (implemented there in C++
//! over CUDA IPC; here the pool lives in host literals fed to PJRT).

/// Free-list allocator over `n_blocks` pool slots with per-owner tracking.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    free: Vec<u32>,
    owner: Vec<Option<u32>>,
    allocated_per_owner: Vec<usize>,
}

impl BlockAllocator {
    pub fn new(n_blocks: usize, n_owners: usize) -> Self {
        BlockAllocator {
            // LIFO free list: recently-freed (cache-warm) blocks reused first.
            free: (0..n_blocks as u32).rev().collect(),
            owner: vec![None; n_blocks],
            allocated_per_owner: vec![0; n_owners],
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.owner.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn used_by(&self, owner: usize) -> usize {
        self.allocated_per_owner[owner]
    }

    /// Allocate `n` blocks for `owner`; returns their pool ids or None if
    /// the pool cannot satisfy the request (all-or-nothing).
    pub fn alloc(&mut self, owner: usize, n: usize) -> Option<Vec<u32>> {
        if self.free.len() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.free.pop().unwrap();
            debug_assert!(self.owner[b as usize].is_none());
            self.owner[b as usize] = Some(owner as u32);
            out.push(b);
        }
        self.allocated_per_owner[owner] += n;
        Some(out)
    }

    /// Return blocks to the pool. Panics on double-free or foreign blocks —
    /// those are correctness bugs upstream.
    pub fn free_blocks(&mut self, owner: usize, blocks: &[u32]) {
        for &b in blocks {
            assert_eq!(
                self.owner[b as usize],
                Some(owner as u32),
                "block {b} not owned by {owner}"
            );
            self.owner[b as usize] = None;
            self.free.push(b);
        }
        self.allocated_per_owner[owner] -= blocks.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proplite, Rng};

    #[test]
    fn alloc_free_round_trip() {
        let mut a = BlockAllocator::new(16, 2);
        let b0 = a.alloc(0, 5).unwrap();
        let b1 = a.alloc(1, 5).unwrap();
        assert_eq!(a.n_free(), 6);
        assert_eq!(a.used_by(0), 5);
        // No overlap between owners.
        assert!(b0.iter().all(|x| !b1.contains(x)));
        a.free_blocks(0, &b0);
        assert_eq!(a.n_free(), 11);
        assert_eq!(a.used_by(0), 0);
    }

    #[test]
    fn all_or_nothing() {
        let mut a = BlockAllocator::new(4, 1);
        assert!(a.alloc(0, 5).is_none());
        assert_eq!(a.n_free(), 4);
        assert!(a.alloc(0, 4).is_some());
        assert!(a.alloc(0, 1).is_none());
    }

    #[test]
    #[should_panic(expected = "not owned")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(4, 1);
        let b = a.alloc(0, 2).unwrap();
        a.free_blocks(0, &b);
        a.free_blocks(0, &b);
    }

    /// Property: any interleaving of allocs/frees conserves blocks, never
    /// double-allocates, and restores full capacity once all users free.
    #[test]
    fn prop_alloc_free_conservation() {
        proplite::check(200, |rng: &mut Rng| {
            let n_blocks = rng.range(1, 64) as usize;
            let n_owners = rng.range(1, 4) as usize;
            let mut a = BlockAllocator::new(n_blocks, n_owners);
            let mut held: Vec<(usize, Vec<u32>)> = Vec::new();
            for _ in 0..rng.range(1, 50) {
                if rng.f64() < 0.6 || held.is_empty() {
                    let owner = rng.below(n_owners);
                    let want = rng.range(1, 8) as usize;
                    if let Some(blocks) = a.alloc(owner, want) {
                        crate::prop_assert!(
                            blocks.len() == want,
                            "short allocation"
                        );
                        held.push((owner, blocks));
                    }
                } else {
                    let i = rng.below(held.len());
                    let (owner, blocks) = held.swap_remove(i);
                    a.free_blocks(owner, &blocks);
                }
                // Invariant: held + free == total, no overlap.
                let held_count: usize =
                    held.iter().map(|(_, b)| b.len()).sum();
                crate::prop_assert!(
                    held_count + a.n_free() == n_blocks,
                    "leak: held={held_count} free={}",
                    a.n_free()
                );
                let mut all: Vec<u32> = held
                    .iter()
                    .flat_map(|(_, b)| b.iter().copied())
                    .collect();
                all.sort();
                let before = all.len();
                all.dedup();
                crate::prop_assert!(all.len() == before, "double allocation");
            }
            for (owner, blocks) in held.drain(..) {
                a.free_blocks(owner, &blocks);
            }
            crate::prop_assert!(a.n_free() == n_blocks, "capacity not restored");
            Ok(())
        });
    }
}
