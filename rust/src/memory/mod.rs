//! Unified KV cache manager (§3.4) — the memory half of MuxServe's
//! resource manager.
//!
//! GPU memory in a unit is split into three partitions: (1) a unified KV
//! cache of small **head-wise blocks** (each block holds K+V of ONE
//! attention head for `block_size` tokens — possible because head size is
//! uniform across the LLM family), (2) a single replica of each LLM's
//! weights shared by its prefill and decode jobs, (3) an activation
//! reserve. This module manages partition (1):
//!
//! * [`QuotaCache`] — counting view used by the scheduler/simulator:
//!   per-LLM token-block quotas (the fairness device of §3.3) with
//!   periodic adaptation that moves blocks from low- to high-utilization
//!   LLMs.
//! * [`BlockAllocator`] — concrete block-id allocator used by the real
//!   PJRT serving path, handing out slots in the shared pools that the
//!   compiled graphs index via block tables.

mod allocator;
mod quota;

pub use allocator::BlockAllocator;
pub use quota::{QuotaCache, QuotaError};

/// Bytes of one head-wise block: K+V, fp16, `block_size` tokens, one head.
pub fn block_bytes(block_size: usize, head_dim: usize) -> f64 {
    (2 * 2 * block_size * head_dim) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_bytes_for_paper_heads() {
        // head_dim 128 (LLaMA/GPT-3), 16-token blocks: 2*2*16*128 = 8 KiB.
        assert_eq!(block_bytes(16, 128), 8192.0);
    }
}
