//! Unified KV cache manager (§3.4) — the memory half of MuxServe's
//! resource manager, grown into a two-tier managed cache.
//!
//! GPU memory in a unit is split into three partitions: (1) a unified KV
//! cache of small **head-wise blocks** (each block holds K+V of ONE
//! attention head for `block_size` tokens — possible because head size is
//! uniform across the LLM family), (2) a single replica of each LLM's
//! weights shared by its prefill and decode jobs, (3) an activation
//! reserve. This module manages partition (1) as a real cache hierarchy:
//!
//! **Device pool → host tier.** The device pool is the HBM-resident block
//! pool every job reads and writes; the optional [`HostTier`] is a
//! capacity-bounded host-DRAM parking lot for *cold decode contexts*,
//! reached over the same link model staged migration prices its KV copies
//! with. Swapping a context out frees device blocks without discarding KV
//! state; swapping it back in is a self-migration through the engine's
//! resume path.
//!
//! **Responsibility split** (who answers "may this block exist?"):
//!
//! * [`QuotaCache`] — *fairness*: counting view used by the scheduler /
//!   simulator, enforcing per-LLM token-block quotas (§3.3) over the
//!   shared device pool, with periodic adaptation that moves quota from
//!   low- to high-utilization LLMs. Shared (prefix) blocks are charged to
//!   their LLM exactly once, no matter how many requests reference them.
//! * [`BlockAllocator`] — *identity and lifetime*: concrete block-id
//!   allocator used by the real PJRT serving path, handing out slots in
//!   the shared pools that compiled graphs index via block tables. Blocks
//!   are refcounted so common prompt prefixes can be referenced by many
//!   requests and are returned to the pool exactly once, when the last
//!   reference drops (copy-on-write: divergent suffixes allocate fresh
//!   blocks instead of touching shared ones).
//! * [`EvictionPolicy`] — *victim choice*: pluggable ranking of which
//!   cold context to push down the hierarchy when the device pool is
//!   under pressure ([`eviction`] ships LRU, SLRU, and GDSF built-ins;
//!   GDSF scores size × recompute cost with the same pricing the
//!   migration planner uses).
//!
//! Every fallible operation across these surfaces returns
//! `Result<_, KvError>` — allocation, quota charge, host-tier charge, and
//! block release share one error type, and a double free is an error at
//! the public boundary rather than a panic.

mod allocator;
pub mod eviction;
mod host;
mod quota;

pub use allocator::BlockAllocator;
pub use eviction::{
    build_policy, EvictCandidate, EvictionKind, EvictionPolicy,
};
pub use host::HostTier;
pub use quota::QuotaCache;

/// One error type for every fallible KV-cache operation: allocator, quota,
/// eviction, and host-tier (swap) paths all speak it, so callers handle
/// memory pressure uniformly instead of matching per-layer error shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The LLM's per-LLM token-block quota would be exceeded.
    QuotaExceeded,
    /// The shared device pool has no free blocks.
    PoolExhausted,
    /// The host-DRAM tier has no room for the swapped-out context.
    HostExhausted,
    /// A block was released that the caller does not hold (double free or
    /// foreign free) — surfaced as an error at the public boundary; the
    /// failed call mutates nothing.
    NotOwned,
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            KvError::QuotaExceeded => "per-LLM block quota exceeded",
            KvError::PoolExhausted => "device block pool exhausted",
            KvError::HostExhausted => "host-tier capacity exhausted",
            KvError::NotOwned => "block not owned by caller",
        };
        f.write_str(s)
    }
}

impl std::error::Error for KvError {}

/// Bytes of one head-wise block: K+V, fp16, `block_size` tokens, one head.
pub fn block_bytes(block_size: usize, head_dim: usize) -> f64 {
    (2 * 2 * block_size * head_dim) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_bytes_for_paper_heads() {
        // head_dim 128 (LLaMA/GPT-3), 16-token blocks: 2*2*16*128 = 8 KiB.
        assert_eq!(block_bytes(16, 128), 8192.0);
    }

    #[test]
    fn kv_error_displays_distinctly() {
        let all = [
            KvError::QuotaExceeded,
            KvError::PoolExhausted,
            KvError::HostExhausted,
            KvError::NotOwned,
        ];
        let mut msgs: Vec<String> =
            all.iter().map(|e| e.to_string()).collect();
        msgs.sort();
        msgs.dedup();
        assert_eq!(msgs.len(), all.len());
    }
}
