//! Host-DRAM tier: a capacity-bounded parking lot for swapped-out decode
//! contexts. Pure block accounting — the swap traffic itself is priced by
//! the engine over the same link model staged migration uses, and swap
//! counters live in the engine's cache stats.

use super::KvError;

/// Capacity-bounded host-side block accounting. `capacity == 0` means no
/// host tier is configured (evictions fall back to recompute).
#[derive(Clone, Copy, Debug, Default)]
pub struct HostTier {
    capacity: usize,
    used: usize,
    peak: usize,
}

impl HostTier {
    pub fn new(capacity: usize) -> Self {
        HostTier { capacity, used: 0, peak: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn used(&self) -> usize {
        self.used
    }

    /// High-water mark of host blocks in use.
    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn free(&self) -> usize {
        self.capacity - self.used
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Charge a swapped-out context's blocks; `KvError::HostExhausted`
    /// leaves the tier unchanged.
    pub fn charge(&mut self, blocks: usize) -> Result<(), KvError> {
        if self.used + blocks > self.capacity {
            return Err(KvError::HostExhausted);
        }
        self.used += blocks;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Release blocks on swap-in (or when a parked context is dropped).
    pub fn release(&mut self, blocks: usize) {
        debug_assert!(
            self.used >= blocks,
            "host release {blocks} > used {}",
            self.used
        );
        self.used = self.used.saturating_sub(blocks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_release_round_trip() {
        let mut h = HostTier::new(100);
        assert!(h.enabled());
        h.charge(60).unwrap();
        h.charge(40).unwrap();
        assert_eq!(h.free(), 0);
        h.release(60);
        assert_eq!(h.used(), 40);
        assert_eq!(h.peak(), 100);
    }

    #[test]
    fn denial_leaves_tier_unchanged() {
        let mut h = HostTier::new(10);
        h.charge(8).unwrap();
        assert_eq!(h.charge(3), Err(KvError::HostExhausted));
        assert_eq!(h.used(), 8);
        assert_eq!(h.peak(), 8);
        let disabled = HostTier::new(0);
        assert!(!disabled.enabled());
    }
}
