//! Serving metrics (§4.1): rate-weighted aggregate throughput, SLO
//! attainment, P99 latency / TTFT / TPOT (Appendix A.1), and — beyond
//! the paper — per-tier goodput (tier-weighted SLO-attained throughput)
//! for multi-SLO workloads.

use crate::util::Summary;
use crate::workload::SloClass;

/// Completion record for one request, emitted by every serving system
/// (simulated or real) in identical form so comparisons are apples-to-apples.
/// `PartialEq` is derived so replay tests can assert bit-identical runs.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub llm: usize,
    pub arrival: f64,
    /// Time the first output token was produced (end of prefill).
    pub first_token: f64,
    /// Time the last token was produced.
    pub finish: f64,
    pub prompt_len: usize,
    pub output_len: usize,
    /// Contention-free reference latency used for the SLO definition.
    pub ideal_latency: f64,
    /// SLO tier the request was submitted under; scales its latency
    /// target ([`SloClass::latency_mult`]) and its goodput weight.
    pub tier: SloClass,
}

impl RequestRecord {
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    /// Time-to-first-token.
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Time-per-output-token over the decode phase.
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        (self.finish - self.first_token) / (self.output_len - 1) as f64
    }

    /// The request's latency target at harness scale `scale`: the tier
    /// multiplier rides on top, so `Standard` keeps the exact pre-tier
    /// definition while interactive tightens it and batch loosens it.
    pub fn slo_target(&self, scale: f64) -> f64 {
        scale * self.ideal_latency * self.tier.latency_mult()
    }

    pub fn meets_slo(&self, scale: f64) -> bool {
        self.latency() <= self.slo_target(scale)
    }
}

/// Aggregated evaluation of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct Evaluation {
    pub n_llms: usize,
    pub duration: f64,
    pub records: Vec<RequestRecord>,
}

impl Evaluation {
    pub fn new(n_llms: usize, duration: f64, records: Vec<RequestRecord>) -> Self {
        Evaluation { n_llms, duration, records }
    }

    /// Completed requests per second for one LLM.
    pub fn llm_throughput(&self, llm: usize) -> f64 {
        self.records.iter().filter(|r| r.llm == llm).count() as f64
            / self.duration
    }

    /// Rate-weighted aggregate throughput (§4.1): per-LLM throughputs
    /// averaged with weights proportional to their arrival rates.
    pub fn aggregate_throughput(&self, rates: &[f64]) -> f64 {
        let total_rate: f64 = rates.iter().sum();
        if total_rate <= 0.0 {
            return 0.0;
        }
        (0..self.n_llms)
            .map(|i| self.llm_throughput(i) * rates[i] / total_rate)
            .sum::<f64>()
            * self.n_llms as f64
    }

    /// Plain total completions per second.
    pub fn total_throughput(&self) -> f64 {
        self.records.len() as f64 / self.duration
    }

    /// Fraction of requests finishing within `scale × ideal` (§4.1).
    pub fn slo_attainment(&self, scale: f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().filter(|r| r.meets_slo(scale)).count() as f64
            / self.records.len() as f64
    }

    /// Tier-weighted goodput: Σ weight over SLO-met completions, per
    /// second. An untiered (all-standard) run is `2.0 ×` its SLO-met
    /// throughput; under overload this is the objective load shedding
    /// maximizes (finish the valuable work, drop the cheap work).
    pub fn goodput(&self, scale: f64) -> f64 {
        self.records
            .iter()
            .filter(|r| r.meets_slo(scale))
            .map(|r| r.tier.weight())
            .sum::<f64>()
            / self.duration
    }

    /// Completions belonging to one tier.
    pub fn tier_completed(&self, tier: SloClass) -> usize {
        self.records.iter().filter(|r| r.tier == tier).count()
    }

    /// Tier-weighted goodput restricted to one tier.
    pub fn tier_goodput(&self, scale: f64, tier: SloClass) -> f64 {
        self.records
            .iter()
            .filter(|r| r.tier == tier && r.meets_slo(scale))
            .map(|r| r.tier.weight())
            .sum::<f64>()
            / self.duration
    }

    /// SLO attainment within one tier; `None` when the tier finished
    /// nothing (explicitly empty, never NaN).
    pub fn tier_slo_attainment(
        &self,
        scale: f64,
        tier: SloClass,
    ) -> Option<f64> {
        let n = self.tier_completed(tier);
        if n == 0 {
            return None;
        }
        let met = self
            .records
            .iter()
            .filter(|r| r.tier == tier && r.meets_slo(scale))
            .count();
        Some(met as f64 / n as f64)
    }

    /// P99 latency within one tier; `None` when the tier is empty.
    pub fn tier_p99_latency(&self, tier: SloClass) -> Option<f64> {
        let mut s = Summary::new();
        s.extend(
            self.records
                .iter()
                .filter(|r| r.tier == tier)
                .map(|r| r.latency()),
        );
        s.try_p99()
    }

    pub fn latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        s.extend(self.records.iter().map(|r| r.latency()));
        s
    }

    pub fn ttft_summary(&self) -> Summary {
        let mut s = Summary::new();
        s.extend(self.records.iter().map(|r| r.ttft()));
        s
    }

    pub fn tpot_summary(&self) -> Summary {
        let mut s = Summary::new();
        s.extend(
            self.records
                .iter()
                .filter(|r| r.output_len > 1)
                .map(|r| r.tpot()),
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(llm: usize, arrival: f64, first: f64, finish: f64, out: usize,
           ideal: f64) -> RequestRecord {
        RequestRecord {
            id: 0,
            llm,
            arrival,
            first_token: first,
            finish,
            prompt_len: 10,
            output_len: out,
            ideal_latency: ideal,
            tier: SloClass::Standard,
        }
    }

    #[test]
    fn latency_components() {
        let r = rec(0, 1.0, 1.5, 3.5, 5, 1.0);
        assert_eq!(r.latency(), 2.5);
        assert_eq!(r.ttft(), 0.5);
        assert_eq!(r.tpot(), 0.5);
        assert!(r.meets_slo(3.0));
        assert!(!r.meets_slo(2.0));
    }

    #[test]
    fn tpot_single_token_is_zero() {
        assert_eq!(rec(0, 0.0, 1.0, 1.0, 1, 1.0).tpot(), 0.0);
    }

    #[test]
    fn slo_attainment_fraction() {
        let ev = Evaluation::new(1, 10.0, vec![
            rec(0, 0.0, 0.5, 1.0, 2, 1.0),  // latency 1.0, meets 2x
            rec(0, 0.0, 4.0, 8.0, 2, 1.0),  // latency 8.0, misses 2x
        ]);
        assert_eq!(ev.slo_attainment(2.0), 0.5);
    }

    #[test]
    fn aggregate_weights_by_rate() {
        // LLM 0 (high rate) completes 10, LLM 1 completes 2.
        let mut records = Vec::new();
        for i in 0..10 {
            records.push(rec(0, i as f64, i as f64 + 0.1, i as f64 + 0.2, 2, 1.0));
        }
        for i in 0..2 {
            records.push(rec(1, i as f64, i as f64 + 0.1, i as f64 + 0.2, 2, 1.0));
        }
        let ev = Evaluation::new(2, 10.0, records);
        assert_eq!(ev.llm_throughput(0), 1.0);
        assert_eq!(ev.llm_throughput(1), 0.2);
        assert_eq!(ev.total_throughput(), 1.2);
        // Weighted: (1.0*0.9 + 0.2*0.1) * 2 = 1.84 with rates 9:1.
        let agg = ev.aggregate_throughput(&[9.0, 1.0]);
        assert!((agg - 1.84).abs() < 1e-12, "agg={agg}");
    }

    #[test]
    fn tier_scales_the_slo_target() {
        // Latency 2.5 vs ideal 1.0: meets 3x as standard, misses as
        // interactive (target halves), meets easily as batch.
        let mut r = rec(0, 1.0, 1.5, 3.5, 5, 1.0);
        assert!(r.meets_slo(3.0));
        r.tier = SloClass::Interactive;
        assert!(!r.meets_slo(3.0));
        assert!((r.slo_target(3.0) - 1.5).abs() < 1e-12);
        r.tier = SloClass::Batch;
        assert!(r.meets_slo(3.0));
    }

    #[test]
    fn goodput_weighs_met_requests_by_tier() {
        let mut fast_int = rec(0, 0.0, 0.5, 1.0, 2, 1.0); // latency 1.0
        fast_int.tier = SloClass::Interactive; // meets 4x (target 2.0)
        let mut slow_batch = rec(0, 0.0, 4.0, 30.0, 2, 1.0); // latency 30
        slow_batch.tier = SloClass::Batch; // misses 4x (target 16.0)
        let mut met_batch = rec(0, 0.0, 1.0, 10.0, 2, 1.0); // latency 10
        met_batch.tier = SloClass::Batch; // meets 4x
        let std_met = rec(0, 0.0, 0.5, 1.0, 2, 1.0);
        let ev = Evaluation::new(
            1,
            10.0,
            vec![fast_int, slow_batch, met_batch, std_met],
        );
        // Met: interactive (4.0) + batch (1.0) + standard (2.0) = 7.0
        // weight over 10 s.
        assert!((ev.goodput(4.0) - 0.7).abs() < 1e-12);
        assert!(
            (ev.tier_goodput(4.0, SloClass::Interactive) - 0.4).abs()
                < 1e-12
        );
        assert!((ev.tier_goodput(4.0, SloClass::Batch) - 0.1).abs() < 1e-12);
        assert_eq!(ev.tier_completed(SloClass::Batch), 2);
        assert_eq!(
            ev.tier_slo_attainment(4.0, SloClass::Batch),
            Some(0.5)
        );
        assert_eq!(ev.tier_slo_attainment(4.0, SloClass::Interactive), Some(1.0));
        assert!(ev.tier_p99_latency(SloClass::Batch).unwrap() >= 10.0);
        // Empty tier: explicitly None, never NaN.
        let none = Evaluation::new(1, 10.0, vec![]);
        assert_eq!(none.tier_slo_attainment(4.0, SloClass::Standard), None);
        assert_eq!(none.tier_p99_latency(SloClass::Standard), None);
        assert_eq!(none.goodput(4.0), 0.0);
    }

    #[test]
    fn summaries_cover_percentiles() {
        let ev = Evaluation::new(1, 1.0, (0..100)
            .map(|i| rec(0, 0.0, 0.1, 0.1 + i as f64, 2, 1.0))
            .collect());
        assert!(ev.latency_summary().p99() > ev.latency_summary().p50());
        assert_eq!(ev.ttft_summary().count(), 100);
    }
}
