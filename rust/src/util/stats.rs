//! Small statistics helpers shared by metrics and the bench harness.

/// Streaming summary of a sample: count / mean / min / max / percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { xs: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        self.xs.extend(xs);
    }

    pub fn count(&self) -> usize {
        self.xs.len()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// `mean` with an explicit empty case instead of NaN. Verdict-style
    /// comparisons must use these: NaN compares false both ways, so a
    /// NaN mean silently falls through `<`/`>=` gates.
    pub fn try_mean(&self) -> Option<f64> {
        if self.xs.is_empty() {
            None
        } else {
            Some(self.mean())
        }
    }

    /// `percentile` with an explicit empty case instead of NaN.
    pub fn try_percentile(&self, p: f64) -> Option<f64> {
        if self.xs.is_empty() {
            None
        } else {
            Some(self.percentile(p))
        }
    }

    pub fn try_p50(&self) -> Option<f64> {
        self.try_percentile(50.0)
    }

    pub fn try_p99(&self) -> Option<f64> {
        self.try_percentile(99.0)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend([3.0, 1.0, 2.0]);
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert_eq!(s.p50(), 2.0);
    }

    #[test]
    fn percentile_of_uniform() {
        let mut s = Summary::new();
        s.extend((0..=100).map(|i| i as f64));
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p99().is_nan());
    }

    #[test]
    fn try_accessors_make_empty_explicit() {
        let empty = Summary::new();
        assert_eq!(empty.try_mean(), None);
        assert_eq!(empty.try_p50(), None);
        assert_eq!(empty.try_p99(), None);
        let mut s = Summary::new();
        s.add(2.0);
        assert_eq!(s.try_mean(), Some(2.0));
        assert_eq!(s.try_p99(), Some(2.0));
    }
}
