//! Shared utilities built from scratch (the offline toolchain has no rand /
//! serde_json / proptest, so this crate carries its own substrates).

pub mod json;
pub mod proplite;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
