//! Tiny property-based testing harness (the offline registry has no
//! proptest). Runs a property over many seeded random cases; on failure it
//! reports the failing seed so the case can be replayed exactly.
//!
//! Usage:
//! ```ignore
//! proplite::check(256, |rng| {
//!     let n = rng.range(1, 100) as usize;
//!     // ... build a case from rng, assert the invariant, return Ok(())
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Result of one property evaluation: Err carries a human-readable
/// counterexample description.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> PropResult>(cases: u64, mut prop: F) {
    // Env override lets a failure be replayed: PROPLITE_SEED=<n>.
    if let Ok(seed) = std::env::var("PROPLITE_SEED") {
        let seed: u64 = seed.parse().expect("PROPLITE_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {seed}/{cases} \
                 (replay: PROPLITE_SEED={}): {msg}",
                0xC0FFEEu64 ^ seed.wrapping_mul(0x9E3779B97F4A7C15)
            );
        }
    }
}

/// Assert helper that returns Err instead of panicking, so `check` can
/// report the seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(64, |rng| {
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay")]
    fn reports_seed_on_failure() {
        check(64, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.5, "x = {x}");
            Ok(())
        });
    }
}
