//! Minimal JSON parser + writer (the offline registry has no serde_json).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the experiment config files: objects, arrays, strings (with escapes),
//! numbers, booleans, null. Numbers are kept as f64 (the manifest only
//! carries shapes/offsets well below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports the missing key.
    pub fn field(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or(JsonError {
            msg: format!("missing field `{key}`"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // -- writer -------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(code)
                                    .unwrap_or(char::REPLACEMENT_CHARACTER),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn round_trips() {
        let src = r#"{"pool":{"block_size":16,"head_dim":64},"xs":[1,2.5,"s",true,null]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn usize_arr_helper() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_arr(), Some(vec![1, 2, 3]));
        assert_eq!(Json::parse("[1, \"x\"]").unwrap().usize_arr(), None);
    }

    #[test]
    fn parses_real_manifest() {
        // Parse the actual AOT manifest if it has been built.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("pool").is_some());
            assert!(!v.get("artifacts").unwrap().as_arr().unwrap().is_empty());
        }
    }
}
