//! Deterministic PRNG (xoshiro256++) plus the samplers the workload layer
//! needs: uniform, exponential (Poisson inter-arrivals), log-normal
//! (ShareGPT-like length marginals), and power-law weights.
//!
//! Written from scratch: the offline registry has no `rand` crate. All
//! experiment code seeds explicitly so every figure is reproducible.

/// xoshiro256++ by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Independent stream derived from this one (for per-LLM generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson gaps.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given *linear-space* mean and sigma (shape).
    pub fn log_normal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        // mean = exp(mu + sigma^2/2)  =>  mu = ln(mean) - sigma^2/2
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn lognormal_mean_close() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean: f64 = (0..n)
            .map(|_| r.log_normal_mean(161.0, 0.8))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 161.0).abs() / 161.0 < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..60_000 {
            counts[r.weighted(&[1.0, 2.0, 3.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 60_000.0;
        assert!((frac - 0.5).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
