//! Real serving path: the coordinator driving actual PJRT executables
//! (the end-to-end proof that all three layers compose).

pub mod engine;

pub use engine::{ServeConfig, ServeReport, ServingEngine};
