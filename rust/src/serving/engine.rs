//! Real multi-LLM serving over PJRT — the end-to-end proof that the three
//! layers compose.
//!
//! Two (or more) AOT-compiled transformers are served *concurrently from a
//! single unified head-wise KV pool*: the rust coordinator owns the pool
//! and the per-request block tables, admits requests with ADBS
//! (prefill-prioritized round-robin + token-block quotas, Alg. 3), batches
//! them into the fixed-batch compiled executables, and advances a virtual
//! clock by each job's measured wall-clock execution time. The CPU PJRT
//! device executes one job at a time, so this path validates functional
//! composition, scheduling order, fairness, and memory sharing; the SM
//! co-location dimension is covered by the simulator.

use std::collections::VecDeque;

use anyhow::{anyhow, Result};

use crate::coordinator::{EngineConfig, Policy};
use crate::memory::{BlockAllocator, QuotaCache};
use crate::metrics::{Evaluation, RequestRecord};
use crate::runtime::executor::{argmax_rows, HostTensor, PjrtRuntime};
use crate::runtime::manifest::ModelEntry;
use crate::util::Rng;
use crate::workload::{Request, SloClass};

/// Serving-run configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub engine: EngineConfig,
    /// Stop admitting after this virtual time (s); 0 = run to completion.
    pub horizon: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { engine: EngineConfig::muxserve(), horizon: 0.0 }
    }
}

/// A request being decoded on the real path.
struct RealActive {
    req: Request,
    /// Prompt then generated tokens (the current tail token's KV is
    /// written by the next decode step).
    tokens: Vec<i32>,
    generated: usize,
    first_token: f64,
    /// Block table, flat [L, H, M].
    table: Vec<u32>,
    /// Blocks per (layer, head) currently backed.
    blocks_per_head: usize,
    /// Every block id held (for freeing).
    held: Vec<u32>,
}

/// Outcome of a serving run.
pub struct ServeReport {
    pub eval: Evaluation,
    /// Total PJRT executions (prefill + decode jobs).
    pub n_jobs: u64,
    /// Total generated tokens.
    pub tokens_out: u64,
    /// Wall-clock seconds spent inside PJRT execute.
    pub busy_time: f64,
    /// Measured per-model (t_prefill_b1, t_decode_b1) calibration.
    pub calibration: Vec<(f64, f64)>,
    /// Peak pool blocks in use.
    pub peak_blocks: usize,
}

/// The real serving engine.
pub struct ServingEngine {
    rt: PjrtRuntime,
    models: Vec<ModelEntry>,
    cfg: ServeConfig,
    alloc: BlockAllocator,
    quota: QuotaCache,
    k_pool: Vec<f32>,
    v_pool: Vec<f32>,
    scratch_block: u32,
    waiting: Vec<VecDeque<Request>>,
    active: Vec<Vec<RealActive>>,
    rr_prefill: usize,
    rr_decode: usize,
    now: f64,
    busy: f64,
    tokens_out: u64,
    peak_blocks: usize,
    records: Vec<RequestRecord>,
    calibration: Vec<(f64, f64)>,
}

impl ServingEngine {
    /// Build an engine serving `model_names` from `artifacts_dir`, with
    /// per-model mean rates (for quota initialisation).
    pub fn new(
        artifacts_dir: impl AsRef<std::path::Path>,
        model_names: &[&str],
        rates: &[f64],
        cfg: ServeConfig,
    ) -> Result<Self> {
        let mut rt = PjrtRuntime::new(artifacts_dir)?;
        let mut models = Vec::new();
        for name in model_names {
            rt.load_model(name)?;
            models.push(
                rt.manifest
                    .models
                    .get(*name)
                    .ok_or_else(|| anyhow!("unknown model {name}"))?
                    .clone(),
            );
        }
        let pool_blocks = rt.manifest.pool_blocks;
        let pool_len = rt.pool_len();
        // Reserve the last block as the padding-row scratch target.
        let scratch_block = (pool_blocks - 1) as u32;
        let weights: Vec<f64> = models
            .iter()
            .zip(rates)
            .map(|(m, r)| {
                let blocks_per_req = (m.n_layers * m.n_heads * 4) as f64;
                (r * blocks_per_req).max(1e-9)
            })
            .collect();
        let n = models.len();
        Ok(ServingEngine {
            rt,
            cfg,
            alloc: BlockAllocator::new(pool_blocks - 1, n),
            quota: QuotaCache::new(pool_blocks - 1, &weights),
            k_pool: vec![0.0; pool_len],
            v_pool: vec![0.0; pool_len],
            scratch_block,
            waiting: vec![VecDeque::new(); n],
            active: (0..n).map(|_| Vec::new()).collect(),
            rr_prefill: 0,
            rr_decode: 0,
            now: 0.0,
            busy: 0.0,
            tokens_out: 0,
            peak_blocks: 0,
            records: Vec::new(),
            calibration: Vec::new(),
            models,
        })
    }

    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    /// Generate a synthetic request stream sized for the compiled models
    /// (prompt ≤ prefill window, prompt+output ≤ max context).
    pub fn gen_requests(
        &self,
        rates: &[f64],
        duration: f64,
        seed: u64,
    ) -> Vec<Request> {
        let mut rng = Rng::new(seed);
        let mut all = Vec::new();
        for (m, rate) in rates.iter().enumerate() {
            let entry = &self.models[m];
            let max_prompt = self.rt.manifest.prefill_seq_len.min(56);
            let mut t = 0.0;
            let mut id = (m as u64) << 40;
            if *rate <= 0.0 {
                continue;
            }
            loop {
                t += rng.exponential(*rate);
                if t >= duration {
                    break;
                }
                let prompt_len = rng.range(4, max_prompt as i64) as usize;
                let max_out =
                    (entry.max_ctx - prompt_len).min(48).max(1) as i64;
                let output_len = rng.range(1, max_out) as usize;
                all.push(Request {
                    id,
                    llm: m,
                    arrival: t,
                    prompt_len,
                    output_len,
                    prefix_group: 0,
                    prefix_len: 0,
                    tier: SloClass::Standard,
                });
                id += 1;
            }
        }
        all.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        all
    }

    /// Measure single-request prefill/decode latency per model (the SLO
    /// reference) and warm the executable cache.
    pub fn calibrate(&mut self) -> Result<()> {
        self.calibration.clear();
        for m in 0..self.models.len() {
            let req = Request {
                id: u64::MAX - m as u64,
                llm: m,
                arrival: 0.0,
                prompt_len: 16,
                output_len: 2,
                prefix_group: 0,
                prefix_len: 0,
                tier: SloClass::Standard,
            };
            let t0 = std::time::Instant::now();
            self.run_prefill_job(m, vec![req])?;
            let t_p = t0.elapsed().as_secs_f64();
            let t1 = std::time::Instant::now();
            self.run_decode_job(m)?;
            let t_d = t1.elapsed().as_secs_f64();
            // Drain the calibration request (1 more decode finishes it).
            while !self.active[m].is_empty() {
                self.run_decode_job(m)?;
            }
            self.calibration.push((t_p, t_d));
        }
        // Calibration must not pollute the report.
        self.records.clear();
        self.now = 0.0;
        self.busy = 0.0;
        self.tokens_out = 0;
        Ok(())
    }

    /// Serve a request stream to completion; returns the report.
    pub fn serve(&mut self, requests: &[Request]) -> Result<ServeReport> {
        if self.calibration.is_empty() {
            self.calibrate()?;
        }
        let mut pending: VecDeque<Request> = requests.iter().cloned().collect();
        let total = requests.len();
        let mut done_guard = 0usize;
        loop {
            // Admit arrivals up to the virtual clock.
            while pending
                .front()
                .map(|r| r.arrival <= self.now)
                .unwrap_or(false)
            {
                let r = pending.pop_front().unwrap();
                self.waiting[r.llm].push_back(r);
            }
            let did = self.schedule_step()?;
            if !did {
                if let Some(next) = pending.front() {
                    // Idle: jump to the next arrival.
                    self.now = next.arrival;
                    continue;
                }
                break; // no work, no arrivals: done
            }
            done_guard += 1;
            if done_guard > total * 1000 + 10_000 {
                anyhow::bail!("serving loop did not converge");
            }
        }
        Ok(ServeReport {
            eval: Evaluation::new(
                self.models.len(),
                self.now.max(1e-9),
                self.records.clone(),
            ),
            n_jobs: self.rt.n_executions,
            tokens_out: self.tokens_out,
            busy_time: self.busy,
            calibration: self.calibration.clone(),
            peak_blocks: self.peak_blocks,
        })
    }

    // -- scheduling (Alg. 3, serial-device edition) -------------------------

    /// One scheduling decision + execution. Returns false when idle.
    fn schedule_step(&mut self) -> Result<bool> {
        match self.cfg.engine.policy {
            Policy::Adbs | Policy::RoundRobin => {
                // Prefill priority, round-robin.
                let n = self.models.len();
                for off in 0..n {
                    let i = (self.rr_prefill + off) % n;
                    if self.waiting[i].is_empty() {
                        continue;
                    }
                    if let Some(batch) = self.admit_prefill(i) {
                        self.rr_prefill = (i + 1) % n;
                        self.run_prefill_job(i, batch)?;
                        return Ok(true);
                    }
                }
                for off in 0..n {
                    let i = (self.rr_decode + off) % n;
                    if self.active[i].is_empty() {
                        continue;
                    }
                    self.rr_decode = (i + 1) % n;
                    self.run_decode_job(i)?;
                    return Ok(true);
                }
                Ok(false)
            }
            Policy::FcfsTemporal => {
                // Oldest unfinished request decides which LLM runs.
                let mut best: Option<(f64, usize, bool)> = None;
                for i in 0..self.models.len() {
                    if let Some(w) = self.waiting[i].front() {
                        let k = (w.arrival, i, true);
                        if best.map_or(true, |b| k.0 < b.0) {
                            best = Some(k);
                        }
                    }
                    if let Some(a) = self.active[i]
                        .iter()
                        .map(|a| a.req.arrival)
                        .min_by(|a, b| a.partial_cmp(b).unwrap())
                    {
                        if best.map_or(true, |b| a < b.0) {
                            best = Some((a, i, false));
                        }
                    }
                }
                match best {
                    Some((_, i, true)) => {
                        if let Some(batch) = self.admit_prefill(i) {
                            self.run_prefill_job(i, batch)?;
                            return Ok(true);
                        }
                        if !self.active[i].is_empty() {
                            self.run_decode_job(i)?;
                            return Ok(true);
                        }
                        Ok(false)
                    }
                    Some((_, i, false)) => {
                        self.run_decode_job(i)?;
                        Ok(true)
                    }
                    None => Ok(false),
                }
            }
        }
    }

    /// Try to admit a prefill batch for model `m` under quota.
    fn admit_prefill(&mut self, m: usize) -> Option<Vec<Request>> {
        let entry = &self.models[m];
        let max_batch =
            *entry.prefill_batches.iter().max().unwrap_or(&1);
        let mut batch = Vec::new();
        while batch.len() < max_batch {
            let Some(front) = self.waiting[m].front() else { break };
            let per_head =
                (front.prompt_len + 1).div_ceil(entry.block_size);
            let need = per_head * entry.n_layers * entry.n_heads;
            let ok = if self.enforce_quota() {
                self.quota.alloc(m, need).is_ok()
            } else {
                self.quota.alloc_pool_only(m, need).is_ok()
            };
            if !ok {
                break;
            }
            // Quota admitted — roll back; the actual ids are allocated in
            // run_prefill_job (quota and allocator stay in lock-step).
            self.quota.free(m, need);
            batch.push(self.waiting[m].pop_front().unwrap());
        }
        if batch.is_empty() {
            None
        } else {
            Some(batch)
        }
    }

    fn enforce_quota(&self) -> bool {
        self.cfg.engine.unified_kv
            && self.cfg.engine.policy == Policy::Adbs
    }

    /// Allocate `per_head` blocks per (layer, head) for a new request.
    fn alloc_table(
        &mut self,
        m: usize,
        per_head: usize,
    ) -> Option<(Vec<u32>, Vec<u32>)> {
        let entry = &self.models[m];
        let (l, h, cap) =
            (entry.n_layers, entry.n_heads, entry.max_blocks_per_seq);
        let need = per_head * l * h;
        if self.enforce_quota() {
            self.quota.alloc(m, need).ok()?;
        } else if self.quota.alloc_pool_only(m, need).is_err() {
            return None;
        }
        let Ok(ids) = self.alloc.alloc(m, need) else {
            self.quota.free(m, need);
            return None;
        };
        // Fill table slots [l][h][0..per_head].
        let mut table = vec![self.scratch_block; l * h * cap];
        let mut it = ids.iter();
        for li in 0..l {
            for hi in 0..h {
                for j in 0..per_head {
                    table[(li * h + hi) * cap + j] = *it.next().unwrap();
                }
            }
        }
        self.peak_blocks =
            self.peak_blocks.max(self.alloc.n_blocks() - self.alloc.n_free());
        Some((table, ids))
    }

    /// Grow a request's table to cover `tokens` context tokens.
    fn grow_table(&mut self, m: usize, idx: usize, tokens: usize) -> bool {
        let entry = self.models[m].clone();
        let (l, h, cap) =
            (entry.n_layers, entry.n_heads, entry.max_blocks_per_seq);
        let want = tokens.div_ceil(entry.block_size).min(cap);
        let have = self.active[m][idx].blocks_per_head;
        if want <= have {
            return true;
        }
        let delta = want - have;
        let need = delta * l * h;
        let ok = if self.enforce_quota() {
            self.quota.alloc(m, need).is_ok()
        } else {
            self.quota.alloc_pool_only(m, need).is_ok()
        };
        if !ok {
            return false;
        }
        let Ok(ids) = self.alloc.alloc(m, need) else {
            self.quota.free(m, need);
            return false;
        };
        let a = &mut self.active[m][idx];
        let mut it = ids.iter();
        for li in 0..l {
            for hi in 0..h {
                for j in have..want {
                    a.table[(li * h + hi) * cap + j] = *it.next().unwrap();
                }
            }
        }
        a.held.extend(ids);
        a.blocks_per_head = want;
        self.peak_blocks =
            self.peak_blocks.max(self.alloc.n_blocks() - self.alloc.n_free());
        true
    }

    fn free_request(&mut self, m: usize, a: &RealActive) {
        // A request's `held` list is exactly what was allocated for it, so
        // a NotOwned here is an engine bug, not a recoverable condition.
        self.alloc
            .free_blocks(m, &a.held)
            .expect("engine frees only blocks it owns");
        self.quota.free(m, a.held.len());
    }

    // -- job execution --------------------------------------------------------

    fn run_prefill_job(&mut self, m: usize, batch: Vec<Request>) -> Result<()> {
        let entry = self.models[m].clone();
        let seq = self.rt.manifest.prefill_seq_len;
        let exec_b = self
            .rt
            .manifest
            .batch_for(&entry.name, "prefill", batch.len())
            .ok_or_else(|| anyhow!("no prefill batches for {}", entry.name))?;
        let (l, h, cap) =
            (entry.n_layers, entry.n_heads, entry.max_blocks_per_seq);

        // Build actives with fresh tables.
        let mut rng = Rng::new(0xF00D ^ batch.first().map(|r| r.id).unwrap_or(0));
        let mut rows: Vec<RealActive> = Vec::new();
        let mut batch_iter = batch.into_iter();
        while let Some(req) = batch_iter.next() {
            let per_head = (req.prompt_len + 1).div_ceil(entry.block_size);
            let Some((table, held)) = self.alloc_table(m, per_head) else {
                // Could not back the request after admission (lost a race
                // with another grow): requeue it AND the rest of the batch
                // (dropping them would strand requests forever).
                self.waiting[m].push_front(req);
                for rest in batch_iter.by_ref() {
                    self.waiting[m].push_back(rest);
                }
                break;
            };
            // Deterministic synthetic prompt tokens.
            let tokens: Vec<i32> = (0..req.prompt_len)
                .map(|_| rng.range(0, entry.vocab_size as i64 - 1) as i32)
                .collect();
            rows.push(RealActive {
                req,
                tokens,
                generated: 0,
                first_token: 0.0,
                table,
                blocks_per_head: per_head,
                held,
            });
        }
        if rows.is_empty() {
            return Ok(());
        }

        // Tensor assembly (padding rows target the scratch block).
        let b = exec_b;
        let mut tokens = vec![0i32; b * seq];
        let mut lens = vec![1i32; b];
        let mut tables = vec![self.scratch_block as i32; b * l * h * cap];
        for (r, a) in rows.iter().enumerate() {
            for (j, t) in a.tokens.iter().enumerate() {
                tokens[r * seq + j] = *t;
            }
            lens[r] = a.tokens.len() as i32;
            for (j, id) in a.table.iter().enumerate() {
                tables[r * l * h * cap + j] = *id as i32;
            }
        }
        let inputs = vec![
            HostTensor::I32(tokens),
            HostTensor::I32(lens),
            HostTensor::I32(tables),
            HostTensor::F32(std::mem::take(&mut self.k_pool)),
            HostTensor::F32(std::mem::take(&mut self.v_pool)),
        ];
        let t0 = std::time::Instant::now();
        let out = self.rt.run_step(&entry.name, "prefill", b, &inputs)?;
        let dur = t0.elapsed().as_secs_f64();
        self.busy += dur;
        self.now += dur;
        self.k_pool = out.k_pool;
        self.v_pool = out.v_pool;

        let next = argmax_rows(&out.logits, entry.vocab_size);
        for (r, mut a) in rows.into_iter().enumerate() {
            a.tokens.push(next[r]);
            a.generated = 1;
            a.first_token = self.now;
            self.tokens_out += 1;
            if a.generated >= a.req.output_len {
                self.finish(m, a);
            } else {
                self.active[m].push(a);
            }
        }
        Ok(())
    }

    fn run_decode_job(&mut self, m: usize) -> Result<()> {
        let entry = self.models[m].clone();
        let (l, h, cap) =
            (entry.n_layers, entry.n_heads, entry.max_blocks_per_seq);
        let max_b = *entry.decode_batches.iter().max().unwrap_or(&1);

        // Select the batch (oldest first) and grow tables; preempt the
        // youngest request on allocation failure (vLLM recompute).
        self.active[m].sort_by(|a, b| {
            a.req.arrival.partial_cmp(&b.req.arrival).unwrap()
        });
        let mut selected: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < self.active[m].len() && selected.len() < max_b {
            let ctx = self.active[m][i].tokens.len();
            if self.grow_table(m, i, ctx) {
                selected.push(i);
                i += 1;
            } else if self.active[m].len() > selected.len() + 1 {
                // Preempt the youngest non-selected request.
                let victim = self.active[m].len() - 1;
                let a = self.active[m].remove(victim);
                self.free_request(m, &a);
                let mut req = a.req;
                req.prompt_len = req.prompt_len.min(56);
                self.waiting[m].push_front(req);
            } else {
                break;
            }
        }
        if selected.is_empty() {
            return Ok(());
        }
        let exec_b = self
            .rt
            .manifest
            .batch_for(&entry.name, "decode", selected.len())
            .ok_or_else(|| anyhow!("no decode batches"))?;
        let b = exec_b;
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut tables = vec![self.scratch_block as i32; b * l * h * cap];
        for (r, &idx) in selected.iter().enumerate() {
            let a = &self.active[m][idx];
            tokens[r] = *a.tokens.last().unwrap();
            positions[r] = (a.tokens.len() - 1) as i32;
            for (j, id) in a.table.iter().enumerate() {
                tables[r * l * h * cap + j] = *id as i32;
            }
        }
        let inputs = vec![
            HostTensor::I32(tokens),
            HostTensor::I32(positions),
            HostTensor::I32(tables),
            HostTensor::F32(std::mem::take(&mut self.k_pool)),
            HostTensor::F32(std::mem::take(&mut self.v_pool)),
        ];
        let t0 = std::time::Instant::now();
        let out = self.rt.run_step(&entry.name, "decode", b, &inputs)?;
        let dur = t0.elapsed().as_secs_f64();
        self.busy += dur;
        self.now += dur;
        self.k_pool = out.k_pool;
        self.v_pool = out.v_pool;

        let next = argmax_rows(&out.logits, entry.vocab_size);
        // Process in reverse index order so removals stay valid.
        for (r, &idx) in selected.iter().enumerate().rev() {
            let a = &mut self.active[m][idx];
            a.tokens.push(next[r]);
            a.generated += 1;
            self.tokens_out += 1;
            if a.generated >= a.req.output_len {
                let a = self.active[m].remove(idx);
                self.finish(m, a);
            }
        }
        Ok(())
    }

    fn finish(&mut self, m: usize, a: RealActive) {
        self.free_request(m, &a);
        let (t_p, t_d) = self.calibration.get(m).copied().unwrap_or((0.1, 0.1));
        let ideal = t_p + t_d * a.req.output_len as f64;
        self.records.push(RequestRecord {
            id: a.req.id,
            llm: m,
            arrival: a.req.arrival,
            first_token: a.first_token,
            finish: self.now,
            prompt_len: a.req.prompt_len,
            output_len: a.req.output_len,
            ideal_latency: ideal,
            tier: a.req.tier,
        });
    }

    /// Expose a greedy-decode helper for correctness checks: generate
    /// `n_tokens` from `prompt` on model `m`, serially (batch 1).
    pub fn generate(
        &mut self,
        m: usize,
        prompt: &[i32],
        n_tokens: usize,
    ) -> Result<Vec<i32>> {
        let req = Request {
            id: 0xDEAD,
            llm: m,
            arrival: 0.0,
            prompt_len: prompt.len(),
            output_len: n_tokens,
            prefix_group: 0,
            prefix_len: 0,
            tier: SloClass::Standard,
        };
        // Run via the normal job path, then recover the sequence.
        let entry = self.models[m].clone();
        let per_head = (prompt.len() + 1).div_ceil(entry.block_size);
        let (table, held) = self
            .alloc_table(m, per_head)
            .ok_or_else(|| anyhow!("pool exhausted"))?;
        let mut a = RealActive {
            req,
            tokens: prompt.to_vec(),
            generated: 0,
            first_token: 0.0,
            table,
            blocks_per_head: per_head,
            held,
        };
        // Prefill (batch 1), bypassing admit so the prompt is exact.
        let seq = self.rt.manifest.prefill_seq_len;
        let (l, h, cap) =
            (entry.n_layers, entry.n_heads, entry.max_blocks_per_seq);
        let mut tokens = vec![0i32; seq];
        tokens[..prompt.len()].copy_from_slice(prompt);
        let tables: Vec<i32> = a.table.iter().map(|x| *x as i32).collect();
        debug_assert_eq!(tables.len(), l * h * cap);
        let inputs = vec![
            HostTensor::I32(tokens),
            HostTensor::I32(vec![prompt.len() as i32]),
            HostTensor::I32(tables),
            HostTensor::F32(std::mem::take(&mut self.k_pool)),
            HostTensor::F32(std::mem::take(&mut self.v_pool)),
        ];
        let out = self.rt.run_step(&entry.name, "prefill", 1, &inputs)?;
        self.k_pool = out.k_pool;
        self.v_pool = out.v_pool;
        a.tokens.push(argmax_rows(&out.logits, entry.vocab_size)[0]);
        a.generated = 1;
        while a.generated < n_tokens {
            let ctx = a.tokens.len();
            let want = ctx.div_ceil(entry.block_size).min(cap);
            if want > a.blocks_per_head {
                let delta = (want - a.blocks_per_head) * l * h;
                self.quota
                    .alloc_pool_only(m, delta)
                    .map_err(|_| anyhow!("pool exhausted"))?;
                let ids = self
                    .alloc
                    .alloc(m, delta)
                    .map_err(|e| anyhow!("pool: {e}"))?;
                let mut it = ids.iter();
                for li in 0..l {
                    for hi in 0..h {
                        for j in a.blocks_per_head..want {
                            a.table[(li * h + hi) * cap + j] =
                                *it.next().unwrap();
                        }
                    }
                }
                a.held.extend(ids);
                a.blocks_per_head = want;
            }
            let tables: Vec<i32> = a.table.iter().map(|x| *x as i32).collect();
            let inputs = vec![
                HostTensor::I32(vec![*a.tokens.last().unwrap()]),
                HostTensor::I32(vec![(a.tokens.len() - 1) as i32]),
                HostTensor::I32(tables),
                HostTensor::F32(std::mem::take(&mut self.k_pool)),
                HostTensor::F32(std::mem::take(&mut self.v_pool)),
            ];
            let out = self.rt.run_step(&entry.name, "decode", 1, &inputs)?;
            self.k_pool = out.k_pool;
            self.v_pool = out.v_pool;
            a.tokens.push(argmax_rows(&out.logits, entry.vocab_size)[0]);
            a.generated += 1;
        }
        let result = a.tokens[prompt.len()..].to_vec();
        self.free_request(m, &a);
        Ok(result)
    }
}
