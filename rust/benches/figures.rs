//! End-to-end benchmark: regenerate every paper figure/table (scaled-down
//! sweeps) and report wall-clock per experiment. `harness = false` (the
//! offline registry has no criterion; this is the repo's own harness).
//!
//! Run: `cargo bench --bench figures`

use muxserve::bench::figures as f;

fn timed<T>(name: &str, run: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = run();
    println!("\n[bench] {name}: {:?}", t0.elapsed());
    out
}

fn main() {
    println!("== MuxServe figure-regeneration benchmark ==");
    let duration = std::env::var("BENCH_DURATION")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120.0);

    timed("fig1 (utilization, 2 LLMs/2 GPUs)", f::fig1);
    timed("fig2 (trace synthesis)", f::fig2);
    timed("fig3 (latency vs SM fraction)", f::fig3);
    timed("fig6 (rate distribution)", f::fig6);
    let fig5 = timed("fig5 (synthetic end-to-end)", || {
        f::fig5(&[0.7, 0.9, 1.3, 1.7, 2.1], &[8.0], duration)
    });
    // Shape assertions: MuxServe holds or wins wherever popularity is
    // skewed (alpha >= 0.9 — at near-uniform popularity and deep overload
    // colocation interference can favor spatial, which the paper also
    // notes for small alpha; see EXPERIMENTS.md §Fig5).
    for alpha in [0.9, 1.3, 1.7, 2.1] {
        let at = |sys: &str| {
            fig5.iter()
                .find(|p| p.alpha == alpha && p.system == sys)
                .map(|p| p.throughput)
                .unwrap_or(0.0)
        };
        let (mux, spa, tmp) = (at("muxserve"), at("spatial"), at("temporal"));
        assert!(
            mux >= 0.95 * spa.max(tmp),
            "alpha={alpha}: mux={mux} spatial={spa} temporal={tmp}"
        );
    }
    timed("fig7 (real-trace end-to-end)", || {
        f::fig7(&[5.0, 10.0, 15.0, 20.0], duration)
    });
    let fig8 = timed("fig8 (placement ablation)", || f::fig8(duration));
    for row in &fig8 {
        assert!(
            row.ours >= 0.9 * row.greedy,
            "{}: ours {} < greedy {}",
            row.scenario,
            row.ours,
            row.greedy
        );
    }
    let (a, _b) = timed("fig9 (scheduling ablation)", || f::fig9(duration));
    // FCFS must multiplex worst.
    let tpt = |rows: &[f::Fig9Row], p: &str| {
        rows.iter().find(|r| r.policy == p).unwrap().throughput
    };
    assert!(tpt(&a, "ADBS") > tpt(&a, "FCFS"), "ADBS must beat FCFS");
    let fig10 = timed("fig10 (resource-manager ablation)", || {
        f::fig10(&[0.7, 1.3, 2.1], duration)
    });
    for alpha in [0.7, 1.3, 2.1] {
        let at = |s: &str| {
            fig10
                .iter()
                .find(|p| p.alpha == alpha && p.stage == s)
                .unwrap()
        };
        assert!(
            at("+compute-mgmt").throughput > at("temporal").throughput,
            "alpha={alpha}: compute management must beat temporal"
        );
    }
    timed("fig11 (P99 latency/TPOT/TTFT)", || {
        f::fig11(&[0.9, 2.1], duration)
    });
    let fig12 = timed("fig12 (estimator validation)", || f::fig12(duration));
    for row in &fig12 {
        let err = (row.predicted - row.simulated).abs()
            / row.simulated.max(1e-9);
        assert!(err < 0.6, "{}: estimator err {err:.2}", row.unit);
    }
    println!("\nall figure benches completed with shape assertions green");
}
