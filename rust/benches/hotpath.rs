//! Hot-path micro-benchmarks for the L3 coordinator (criterion substitute:
//! warmup + repeated timing with mean/min reporting).
//!
//! Run: `cargo bench --bench hotpath`

use muxserve::config::{llama_spec, synthetic_zoo, ClusterSpec, WorkloadSpec};
use muxserve::coordinator::estimator::{Estimator, UnitMember};
use muxserve::coordinator::{
    enumerate_mesh_groups, muxserve_placement, EngineConfig,
};
use muxserve::costmodel::CostModel;
use muxserve::memory::{BlockAllocator, QuotaCache};
use muxserve::simulator::Simulation;
use muxserve::util::Rng;
use muxserve::workload::{power_law_rates, synthetic_workload};

/// Time `iters` runs of `f` after `warmup` runs; returns (mean, min) ns.
fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let human = |ns: f64| {
        if ns > 1e9 {
            format!("{:.2} s", ns / 1e9)
        } else if ns > 1e6 {
            format!("{:.2} ms", ns / 1e6)
        } else if ns > 1e3 {
            format!("{:.2} us", ns / 1e3)
        } else {
            format!("{ns:.0} ns")
        }
    };
    println!("{name:<44} mean {:>10}  min {:>10}", human(mean), human(min));
}

fn main() {
    println!("== L3 hot-path micro-benchmarks ==");

    // Block allocator: the per-token-step path of the real engine.
    bench("allocator: alloc+free 64 blocks", 100, 2000, || {
        let mut a = BlockAllocator::new(4096, 4);
        for owner in 0..4 {
            let ids = a.alloc(owner, 16).unwrap();
            a.free_blocks(owner, &ids).unwrap();
        }
    });

    // Quota accounting: every admission/growth decision.
    bench("quota: alloc/free/adapt cycle", 100, 2000, || {
        let mut q = QuotaCache::new(100_000, &[3.0, 2.0, 1.0, 1.0]);
        for llm in 0..4 {
            let _ = q.alloc(llm, 500);
        }
        q.adapt();
        for llm in 0..4 {
            q.free(llm, 500);
        }
    });

    // Eq. 3 estimator: called O(M * D * meshes) during placement.
    let est = Estimator::new(CostModel::a100());
    let members: Vec<UnitMember> = [6.7, 13.0, 30.0]
        .iter()
        .enumerate()
        .map(|(i, p)| UnitMember {
            spec: llama_spec(&format!("b{i}"), *p),
            workload: WorkloadSpec::sharegpt(2.0),
            prefill_sm: 0.5,
            decode_sm: 0.5,
            tp: 4,
        })
        .collect();
    bench("estimator: 3-LLM unit fixpoint", 100, 2000, || {
        est.unit_estimate(&members, 4)
    });

    // Mesh-group enumeration for the paper testbed.
    let cluster = ClusterSpec::paper_testbed();
    bench("placement: mesh-group enumeration (32 GPUs)", 10, 200, || {
        enumerate_mesh_groups(&cluster)
    });

    // Full Alg. 1 at paper scale (19 LLMs / 32 GPUs).
    let specs = synthetic_zoo();
    let workloads: Vec<WorkloadSpec> = power_law_rates(19, 0.9, 20.0)
        .into_iter()
        .map(WorkloadSpec::sharegpt)
        .collect();
    bench("placement: Alg.1 end-to-end (19 LLMs, 32 GPUs)", 1, 5, || {
        muxserve_placement(&specs, &workloads, &cluster, &est).unwrap()
    });

    // Simulator event throughput: events/s on a busy unit.
    let (wl, requests) = synthetic_workload(19, 0.9, 20.0, 60.0, 7);
    let placement =
        muxserve_placement(&specs, &wl, &cluster, &est).unwrap();
    let cost = CostModel::a100();
    let n_req = requests.len();
    bench(
        &format!("simulator: 60s cluster sim ({n_req} reqs)"),
        1,
        10,
        || {
            let mut sim = Simulation::from_placement(
                &placement, &specs, &wl, EngineConfig::muxserve(), &cost,
            );
            sim.run(&requests, 60.0)
        },
    );

    // Workload generation.
    bench("workload: 19-LLM 120s synthesis", 5, 50, || {
        synthetic_workload(19, 0.9, 20.0, 120.0, 3)
    });

    // RNG throughput (underlies everything stochastic).
    let mut rng = Rng::new(1);
    bench("rng: 10k lognormal samples", 10, 500, || {
        (0..10_000)
            .map(|_| rng.log_normal_mean(161.0, 0.8))
            .sum::<f64>()
    });
}
